//! PJRT runtime (feature `pjrt`): loads AOT HLO-text artifacts and
//! executes them from rust.
//!
//! The bridge follows /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (jax >= 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids). Every module from `artifacts/manifest.json` is compiled
//! once on first use and cached; python is never on the request path.
//!
//! PJRT handles are `Rc`-based (not `Send`) — the whole runtime lives on
//! the engine thread by construction. [`PjRtBackend`] adapts the runtime
//! to the backend trait the pipeline drives; inputs arrive bucket-padded
//! (the pipeline owns the padding contract), so every launch is a static
//! shape the AOT artifacts were lowered at.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::FromRawBytes;

use crate::cpu_attn::Numerics;
use crate::exec::arena::TensorArena;
use crate::exec::modules::ExpertSel;
use crate::exec::tensor::{HostTensor, TensorView};
use crate::runtime::{Backend, RtConfig};
use crate::util::json::Json;

/// One lowered module variant (a module × bucket).
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub file: String,
    /// Primary bucket size: token/expert rows, or batch for attention.
    pub bucket: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub num_outputs: usize,
}

/// Parsed artifact registry.
pub struct Artifacts {
    pub dir: PathBuf,
    pub cfg: RtConfig,
    /// name -> variants sorted by ascending bucket.
    by_name: HashMap<String, Vec<ModuleSpec>>,
    pub weights_file: PathBuf,
    pub golden_file: PathBuf,
}

impl Artifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json (run `make artifacts`)",
                    dir.display()
                )
            })?;
        let m = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let cfg = RtConfig::from_json(m.req("config"))?;

        let mut by_name: HashMap<String, Vec<ModuleSpec>> = HashMap::new();
        for e in m.req("modules").as_arr().unwrap_or_default() {
            let name = e.req("name").as_str().unwrap_or_default().to_string();
            let meta = e.req("meta");
            let bucket = meta
                .get("tokens")
                .or_else(|| meta.get("batch"))
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("module {name}: no bucket in meta"))?;
            let params = e.req("params").as_arr().unwrap_or_default();
            let spec = ModuleSpec {
                name: name.clone(),
                file: e.req("file").as_str().unwrap_or_default().to_string(),
                bucket,
                param_names: params
                    .iter()
                    .map(|p| p.req("name").as_str().unwrap_or_default().to_string())
                    .collect(),
                param_shapes: params.iter().map(|p| p.req("shape").usize_arr()).collect(),
                num_outputs: e.req("outputs").as_arr().map(|a| a.len()).unwrap_or(1),
            };
            by_name.entry(name).or_default().push(spec);
        }
        for v in by_name.values_mut() {
            v.sort_by_key(|s| s.bucket);
        }
        let weights_file = dir.join(
            m.get("weights_file")
                .and_then(Json::as_str)
                .unwrap_or("weights.npz"),
        );
        let golden_file = dir.join(
            m.get("golden_file")
                .and_then(Json::as_str)
                .unwrap_or("golden.npz"),
        );
        Ok(Artifacts { dir, cfg, by_name, weights_file, golden_file })
    }

    /// Smallest variant of `name` whose bucket >= `rows`.
    pub fn variant(&self, name: &str, rows: usize) -> Result<&ModuleSpec> {
        let vs = self
            .by_name
            .get(name)
            .ok_or_else(|| anyhow!("unknown module {name}"))?;
        vs.iter().find(|s| s.bucket >= rows).ok_or_else(|| {
            anyhow!(
                "{name}: no bucket fits {rows} rows (max {})",
                vs.last().map(|s| s.bucket).unwrap_or(0)
            )
        })
    }

    pub fn buckets(&self, name: &str) -> Vec<usize> {
        self.by_name
            .get(name)
            .map(|v| v.iter().map(|s| s.bucket).collect())
            .unwrap_or_default()
    }

    pub fn module_names(&self) -> Vec<&str> {
        self.by_name.keys().map(|s| s.as_str()).collect()
    }
}

/// Host-resident weight store (the paper's "model weights in host
/// memory"): name -> Literal, loaded once from weights.npz.
pub struct WeightStore {
    weights: HashMap<String, Rc<xla::Literal>>,
    pub total_bytes: usize,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<Self> {
        let pairs = xla::Literal::read_npz(path, &())
            .with_context(|| format!("reading {}", path.display()))?;
        let mut total = 0usize;
        let mut weights = HashMap::new();
        for (name, lit) in pairs {
            total += lit.size_bytes();
            weights.insert(name, Rc::new(lit));
        }
        Ok(WeightStore { weights, total_bytes: total })
    }

    pub fn get(&self, name: &str) -> Result<Rc<xla::Literal>> {
        self.weights
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("missing weight {name}"))
    }

    /// Bytes of one named weight.
    pub fn bytes(&self, name: &str) -> usize {
        self.weights.get(name).map(|l| l.size_bytes()).unwrap_or(0)
    }

    pub fn names(&self) -> Vec<&str> {
        self.weights.keys().map(|s| s.as_str()).collect()
    }
}

/// The PJRT runtime: device client + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts: Artifacts,
    pub weights: WeightStore,
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Device-resident weight buffers (the live analog of the paper's
    /// `S_Params` GPU parameter cache): uploaded once on first use so hot
    /// modules stop re-copying weights host→device on every launch.
    weight_bufs: RefCell<HashMap<String, Rc<xla::PjRtBuffer>>>,
    /// Cumulative compile time (artifact -> executable), for reporting.
    pub compile_secs: RefCell<f64>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let artifacts = Artifacts::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let weights = WeightStore::load(&artifacts.weights_file)?;
        Ok(Runtime {
            client,
            artifacts,
            weights,
            execs: RefCell::new(HashMap::new()),
            weight_bufs: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    /// Device-resident buffer for a named weight (uploaded on first use,
    /// cached — the `S_Params` cache). Returns the buffer plus whether
    /// this call performed the upload (for traffic accounting).
    pub fn weight_buffer(&self, name: &str) -> Result<(Rc<xla::PjRtBuffer>, bool)> {
        if let Some(b) = self.weight_bufs.borrow().get(name) {
            return Ok((Rc::clone(b), false));
        }
        let lit = self.weights.get(name)?;
        let buf = Rc::new(self.upload(&lit)?);
        self.weight_bufs
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&buf));
        Ok((buf, true))
    }

    /// Upload a literal to the device as a fresh buffer.
    ///
    /// Uses `buffer_from_host_buffer` (kImmutableOnlyDuringCall — data is
    /// copied *during* the call), NOT `buffer_from_host_literal`: the TFRT
    /// CPU client's BufferFromHostLiteral copies asynchronously and would
    /// read freed memory once a temporary literal is dropped.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        #[allow(unreachable_patterns)] // real bindings have more dtypes
        let buf = match lit.ty()? {
            xla::ElementType::S32 => self
                .client
                .buffer_from_host_buffer(&lit.to_vec::<i32>()?, &dims, None)?,
            xla::ElementType::F32 => self
                .client
                .buffer_from_host_buffer(&lit.to_vec::<f32>()?, &dims, None)?,
            other => bail!("upload: unsupported element type {other:?}"),
        };
        Ok(buf)
    }

    /// Direct host-slice → device-buffer upload (skips the intermediate
    /// Literal copy — see EXPERIMENTS.md §Perf).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Direct i32 upload (token ids, lengths, positions).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute a module variant with device buffers as arguments (weights
    /// from the `S_Params` cache + freshly uploaded activations).
    pub fn execute_b(
        &self,
        spec: &ModuleSpec,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        if args.len() != spec.param_names.len() {
            bail!(
                "{}: expected {} args, got {}",
                spec.name,
                spec.param_names.len(),
                args.len()
            );
        }
        let exe = self.executable(spec)?;
        let bufs = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let result = bufs[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    pub fn cfg(&self) -> &RtConfig {
        &self.artifacts.cfg
    }

    /// Compile (or fetch cached) the executable for a module variant.
    pub fn executable(&self, spec: &ModuleSpec) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(&spec.file) {
            return Ok(Rc::clone(e));
        }
        let t0 = std::time::Instant::now();
        let path = self.artifacts.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        self.execs
            .borrow_mut()
            .insert(spec.file.clone(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Eagerly compile every variant of the given modules (warm-up, so the
    /// serving loop never hits a compile stall).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for name in names {
            for b in self.artifacts.buckets(name) {
                let spec = self.artifacts.variant(name, b)?.clone();
                self.executable(&spec)?;
            }
        }
        Ok(())
    }

    /// Execute a module variant with the given argument literals. Returns
    /// the decomposed output tuple.
    pub fn execute(&self, spec: &ModuleSpec, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != spec.param_names.len() {
            bail!(
                "{}: expected {} args, got {}",
                spec.name,
                spec.param_names.len(),
                args.len()
            );
        }
        let exe = self.executable(spec)?;
        let bufs = exe.execute::<&xla::Literal>(args)?;
        let result = bufs[0][0].to_literal_sync()?;
        // Modules are lowered with return_tuple=True.
        Ok(result.to_tuple()?)
    }

    /// Convenience: resolve variant by rows then execute.
    pub fn run(&self, name: &str, rows: usize, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.artifacts.variant(name, rows)?.clone();
        self.execute(&spec, args)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// f32 literal with shape `dims`.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n, "lit_f32 shape mismatch");
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// i32 literal with shape `dims`.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n, "lit_i32 shape mismatch");
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// Extract f32 data from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract i32 data from a literal.
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

// ---------------------------------------------------------------------------
// Backend adapter
// ---------------------------------------------------------------------------

/// The live PJRT execution backend: bucket-padded host tensors in,
/// bucket-sized host tensors out, AOT HLO module programs in between.
pub struct PjRtBackend {
    pub rt: Runtime,
    uploaded_bytes: usize,
}

impl PjRtBackend {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Ok(PjRtBackend { rt: Runtime::new(artifacts_dir)?, uploaded_bytes: 0 })
    }

    /// Fetch weights as device-resident buffers (`S_Params` cache),
    /// charging first-upload traffic to the backend's upload counter.
    fn weight_bufs(&mut self, names: &[String]) -> Result<Vec<Rc<xla::PjRtBuffer>>> {
        let mut bufs = Vec::with_capacity(names.len());
        for n in names {
            let (b, uploaded) = self.rt.weight_buffer(n)?;
            if uploaded {
                self.uploaded_bytes += self.rt.weights.bytes(n);
            }
            bufs.push(b);
        }
        Ok(bufs)
    }
}

impl Backend for PjRtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn cfg(&self) -> &RtConfig {
        self.rt.cfg()
    }

    fn embed(&mut self, ids: &[i32]) -> Result<HostTensor> {
        let h = self.rt.cfg().hidden_size;
        let bucket = ids.len();
        let w = self.weight_bufs(&["emb".into()])?;
        let ids_b = self.rt.upload_i32(ids, &[bucket])?;
        let spec = self.rt.artifacts.variant("embed", bucket)?.clone();
        let outs = self.rt.execute_b(&spec, &[w[0].as_ref(), &ids_b])?;
        Ok(HostTensor::from_vec(to_f32(&outs[0])?, h))
    }

    fn pre_attention(
        &mut self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
        _arena: &mut TensorArena,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let c = self.rt.cfg().clone();
        let (h, qd, kvd) = (c.hidden_size, c.q_dim(), c.kv_dim());
        let bucket = x.rows;
        let p = format!("l{layer}.");
        let names: Vec<String> =
            ["ln1", "wq", "wk", "wv"].iter().map(|s| format!("{p}{s}")).collect();
        let w = self.weight_bufs(&names)?;
        let x_b = self.rt.upload_f32(&x.data, &[bucket, h])?;
        let pos_b = self.rt.upload_i32(pos, &[bucket])?;
        let spec = self.rt.artifacts.variant("pre_attention", bucket)?.clone();
        let args: Vec<&xla::PjRtBuffer> =
            w.iter().map(|l| l.as_ref()).chain([&x_b, &pos_b]).collect();
        let outs = self.rt.execute_b(&spec, &args)?;
        Ok((
            HostTensor::from_vec(to_f32(&outs[0])?, qd),
            HostTensor::from_vec(to_f32(&outs[1])?, kvd),
            HostTensor::from_vec(to_f32(&outs[2])?, kvd),
        ))
    }

    fn attn_prefill(
        &mut self,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        lens: &[i32],
        seq: usize,
    ) -> Result<HostTensor> {
        let c = self.rt.cfg().clone();
        let (nh, nkv, hd) = (c.num_heads, c.num_kv_heads, c.head_dim);
        let bucket = q.rows;
        let q_l = lit_f32(&q.data, &[bucket, seq, nh, hd])?;
        let k_l = lit_f32(&k.data, &[bucket, seq, nkv, hd])?;
        let v_l = lit_f32(&v.data, &[bucket, seq, nkv, hd])?;
        let lens_l = lit_i32(lens, &[bucket])?;
        let spec = self.rt.artifacts.variant("attn_prefill", bucket)?.clone();
        let outs = self.rt.execute(&spec, &[&q_l, &k_l, &v_l, &lens_l])?;
        Ok(HostTensor::from_vec(to_f32(&outs[0])?, seq * c.q_dim()))
    }

    fn attn_decode(
        &mut self,
        q: &HostTensor,
        k_win: &HostTensor,
        v_win: &HostTensor,
        lens: &[i32],
    ) -> Result<HostTensor> {
        let c = self.rt.cfg().clone();
        let (nh, nkv, hd) = (c.num_heads, c.num_kv_heads, c.head_dim);
        let cap = c.max_context;
        let bucket = q.rows;
        let q_l = lit_f32(&q.data, &[bucket, nh, hd])?;
        let k_l = lit_f32(&k_win.data, &[bucket, cap, nkv, hd])?;
        let v_l = lit_f32(&v_win.data, &[bucket, cap, nkv, hd])?;
        let lens_l = lit_i32(lens, &[bucket])?;
        let spec = self.rt.artifacts.variant("attn_decode", bucket)?.clone();
        let outs = self.rt.execute(&spec, &[&q_l, &k_l, &v_l, &lens_l])?;
        Ok(HostTensor::from_vec(to_f32(&outs[0])?, c.q_dim()))
    }

    fn post_attention(
        &mut self,
        layer: usize,
        ctx: &HostTensor,
        resid: &HostTensor,
        _arena: &mut TensorArena,
    ) -> Result<HostTensor> {
        let c = self.rt.cfg().clone();
        let (h, qd) = (c.hidden_size, c.q_dim());
        let bucket = resid.rows;
        let w = self.weight_bufs(&[format!("l{layer}.wo")])?;
        let ctx_b = self.rt.upload_f32(&ctx.data, &[bucket, qd])?;
        let res_b = self.rt.upload_f32(&resid.data, &[bucket, h])?;
        let spec = self.rt.artifacts.variant("post_attention", bucket)?.clone();
        let outs = self
            .rt
            .execute_b(&spec, &[w[0].as_ref(), &ctx_b, &res_b])?;
        Ok(HostTensor::from_vec(to_f32(&outs[0])?, h))
    }

    fn router(
        &mut self,
        layer: usize,
        x: &HostTensor,
        _arena: &mut TensorArena,
    ) -> Result<(HostTensor, Vec<i32>, HostTensor)> {
        let c = self.rt.cfg().clone();
        let (h, k) = (c.hidden_size, c.top_k);
        let bucket = x.rows;
        let p = format!("l{layer}.");
        let w = self.weight_bufs(&[format!("{p}ln2"), format!("{p}wr")])?;
        let x_b = self.rt.upload_f32(&x.data, &[bucket, h])?;
        let spec = self.rt.artifacts.variant("router", bucket)?.clone();
        let outs = self
            .rt
            .execute_b(&spec, &[w[0].as_ref(), w[1].as_ref(), &x_b])?;
        Ok((
            HostTensor::from_vec(to_f32(&outs[0])?, h),
            to_i32(&outs[1])?,
            HostTensor::from_vec(to_f32(&outs[2])?, k),
        ))
    }

    fn expert_ffn(
        &mut self,
        layer: usize,
        sel: ExpertSel,
        x: TensorView<'_>,
        _arena: &mut TensorArena,
    ) -> Result<HostTensor> {
        let h = self.rt.cfg().hidden_size;
        let bucket = x.rows;
        let p = match sel {
            ExpertSel::Routed(e) => format!("l{layer}.e{e}."),
            ExpertSel::Shared => format!("l{layer}.se."),
        };
        let w = self.weight_bufs(&[format!("{p}wg"), format!("{p}wu"), format!("{p}wd")])?;
        let x_b = self.rt.upload_f32(x.data, &[bucket, h])?;
        let spec = self.rt.artifacts.variant("expert_ffn", bucket)?.clone();
        let outs = self
            .rt
            .execute_b(&spec, &[w[0].as_ref(), w[1].as_ref(), w[2].as_ref(), &x_b])?;
        Ok(HostTensor::from_vec(to_f32(&outs[0])?, h))
    }

    fn lm_head(&mut self, x: &HostTensor) -> Result<Vec<i32>> {
        let h = self.rt.cfg().hidden_size;
        let bucket = x.rows;
        let w = self.weight_bufs(&["lnf".into(), "lm_head".into()])?;
        let x_b = self.rt.upload_f32(&x.data, &[bucket, h])?;
        let spec = self.rt.artifacts.variant("lm_head", bucket)?.clone();
        let outs = self
            .rt
            .execute_b(&spec, &[w[0].as_ref(), w[1].as_ref(), &x_b])?;
        to_i32(&outs[0])
    }

    fn take_uploaded_bytes(&mut self) -> usize {
        std::mem::take(&mut self.uploaded_bytes)
    }

    fn weights_total_bytes(&self) -> usize {
        self.rt.weights.total_bytes
    }

    fn cpu_attn_numerics(&self) -> Numerics {
        // The XLA artifacts accumulate in bf16-rounded steps; the paper's
        // App. B consistency contract applies (see crate::cpu_attn).
        Numerics::Bf16Consistent
    }

    fn warmup(&mut self) -> Result<()> {
        let names: Vec<&str> = vec![
            "embed", "pre_attention", "attn_prefill", "attn_decode",
            "post_attention", "router", "expert_ffn", "lm_head",
        ];
        self.rt.warmup(&names)
    }

    fn compile_secs(&self) -> f64 {
        *self.rt.compile_secs.borrow()
    }
}
