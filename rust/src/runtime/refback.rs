//! Reference backend: a pure-rust interpreter of every module's math.
//!
//! This is the rust analog of `python/compile/kernels/ref.py` — straight
//! loops, f32 accumulation, no blocking — serving two jobs:
//!
//! 1. **Hermetic execution**: `cargo test` and the examples run the whole
//!    engine/pipeline stack with no artifacts and no XLA toolchain.
//! 2. **Numerical ground truth**: decode attention is literally the
//!    ω-split CPU kernel ([`crate::cpu_attn`]) in `F32` mode, so the CPU
//!    and "device" attention paths agree bit-for-bit and greedy tokens
//!    cannot depend on where attention ran.
//!
//! Weights are generated deterministically (xorshift RNG, fixed seed) with
//! the same shapes/scales as `python/compile/model.py::init_weights`.
//! Weight-fetch traffic is modeled like the PJRT `S_Params` cache: the
//! first time a module touches a weight it "uploads" it (bytes reported
//! through [`Backend::take_uploaded_bytes`]), afterwards it is resident.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::cpu_attn::{decode_attention, Numerics, SeqAttn};
use crate::exec::arena::TensorArena;
use crate::exec::modules::ExpertSel;
use crate::exec::tensor::{HostTensor, TensorView};
use crate::runtime::{Backend, RtConfig};
use crate::util::rng::Rng;

pub struct RefBackend {
    cfg: RtConfig,
    weights: HashMap<String, Vec<f32>>,
    resident: HashSet<String>,
    uploaded_bytes: usize,
    total_bytes: usize,
    /// Router softmax scratch, reused across tokens and calls (one
    /// allocation for the backend's lifetime instead of two per token).
    probs_scratch: Vec<f32>,
}

impl RefBackend {
    /// Fixed weight seed: the reference model is one model, not one per
    /// engine config (golden traces must be stable across runs).
    pub const WEIGHT_SEED: u64 = 0;

    pub fn new(cfg: RtConfig, seed: u64) -> Self {
        let weights = gen_weights(&cfg, seed);
        let total_bytes = weights.values().map(|w| w.len() * 4).sum();
        RefBackend {
            cfg,
            weights,
            resident: HashSet::new(),
            uploaded_bytes: 0,
            total_bytes,
            probs_scratch: Vec::new(),
        }
    }

    fn weight(&self, name: &str) -> Result<&[f32]> {
        self.weights
            .get(name)
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow!("missing weight {name}"))
    }

    /// Model the `S_Params` upload: first touch of a weight costs its bytes.
    fn touch(&mut self, names: &[String]) {
        for n in names {
            if self.resident.insert(n.clone()) {
                self.uploaded_bytes += self.weights.get(n).map(|w| w.len() * 4).unwrap_or(0);
            }
        }
    }

    fn expert_prefix(&self, layer: usize, sel: ExpertSel) -> String {
        match sel {
            ExpertSel::Routed(e) => format!("l{layer}.e{e}."),
            ExpertSel::Shared => format!("l{layer}.se."),
        }
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref-cpu"
    }

    fn cfg(&self) -> &RtConfig {
        &self.cfg
    }

    fn embed(&mut self, ids: &[i32]) -> Result<HostTensor> {
        self.touch(&["emb".to_string()]);
        let h = self.cfg.hidden_size;
        let emb = self.weight("emb")?;
        let mut out = HostTensor::zeros(ids.len(), h);
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            if id >= self.cfg.vocab_size {
                bail!("token id {id} out of vocabulary");
            }
            out.row_mut(i).copy_from_slice(&emb[id * h..(id + 1) * h]);
        }
        Ok(out)
    }

    fn pre_attention(
        &mut self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
        arena: &mut TensorArena,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let c = self.cfg.clone();
        let (h, qd, kvd, hd) = (c.hidden_size, c.q_dim(), c.kv_dim(), c.head_dim);
        assert_eq!(x.dim, h);
        assert_eq!(x.rows, pos.len());
        let p = format!("l{layer}.");
        let names: Vec<String> =
            ["ln1", "wq", "wk", "wv"].iter().map(|s| format!("{p}{s}")).collect();
        self.touch(&names);

        let xn = rmsnorm_arena(x, self.weight(&names[0])?, c.rms_eps, arena);
        let mut q = matmul_view(xn.view(), self.weight(&names[1])?, qd, arena);
        let mut k = matmul_view(xn.view(), self.weight(&names[2])?, kvd, arena);
        let v = matmul_view(xn.view(), self.weight(&names[3])?, kvd, arena);
        arena.put(xn);
        rope(&mut q, pos, hd, c.rope_theta);
        rope(&mut k, pos, hd, c.rope_theta);
        Ok((q, k, v))
    }

    fn attn_prefill(
        &mut self,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        lens: &[i32],
        seq: usize,
    ) -> Result<HostTensor> {
        let c = &self.cfg;
        let (nh, nkv, hd) = (c.num_heads, c.num_kv_heads, c.head_dim);
        let (qd, kvd) = (c.q_dim(), c.kv_dim());
        let b = q.rows;
        assert_eq!(q.dim, seq * qd);
        assert_eq!(k.dim, seq * kvd);
        assert_eq!(lens.len(), b);
        let group = nh / nkv;
        let scale = 1.0 / (hd as f32).sqrt();

        let mut ctx = HostTensor::zeros(b, seq * qd);
        for bi in 0..b {
            let len = lens[bi] as usize;
            let kr = k.row(bi);
            let vr = v.row(bi);
            let qr = q.row(bi);
            let out = ctx.row_mut(bi);
            for i in 0..len.min(seq) {
                for hq in 0..nh {
                    let kvh = hq / group;
                    let qv = &qr[i * qd + hq * hd..i * qd + (hq + 1) * hd];
                    // Causal + length mask: keys j <= i (and j < len).
                    let mut scores = Vec::with_capacity(i + 1);
                    let mut max = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let kv = &kr[j * kvd + kvh * hd..j * kvd + (kvh + 1) * hd];
                        let mut acc = 0.0f32;
                        for d in 0..hd {
                            acc += qv[d] * kv[d];
                        }
                        let s = acc * scale;
                        scores.push(s);
                        max = max.max(s);
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - max).exp();
                        denom += *s;
                    }
                    let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
                    let o = &mut out[i * qd + hq * hd..i * qd + (hq + 1) * hd];
                    for (j, p) in scores.iter().enumerate() {
                        let w = p * inv;
                        let vv = &vr[j * kvd + kvh * hd..j * kvd + (kvh + 1) * hd];
                        for d in 0..hd {
                            o[d] += w * vv[d];
                        }
                    }
                }
            }
        }
        Ok(ctx)
    }

    fn attn_decode(
        &mut self,
        q: &HostTensor,
        k_win: &HostTensor,
        v_win: &HostTensor,
        lens: &[i32],
    ) -> Result<HostTensor> {
        let c = &self.cfg;
        let (nh, nkv, hd) = (c.num_heads, c.num_kv_heads, c.head_dim);
        let (qd, kvd) = (c.q_dim(), c.kv_dim());
        let b = q.rows;
        assert_eq!(q.dim, qd);
        assert_eq!(k_win.dim, c.max_context * kvd);
        assert_eq!(lens.len(), b);

        // Literally the ω-split CPU kernel in F32 mode: device and CPU
        // attention share one arithmetic path on this backend.
        let seqs: Vec<SeqAttn<'_>> = (0..b)
            .map(|i| {
                let len = (lens[i] as usize).min(c.max_context);
                SeqAttn {
                    q: q.row(i),
                    k: &k_win.row(i)[..len * kvd],
                    v: &v_win.row(i)[..len * kvd],
                    len,
                }
            })
            .collect();
        let mut out = vec![Vec::new(); b];
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut out, 1);
        let mut ctx = HostTensor::zeros(b, qd);
        for (i, o) in out.iter().enumerate() {
            ctx.row_mut(i).copy_from_slice(o);
        }
        Ok(ctx)
    }

    fn post_attention(
        &mut self,
        layer: usize,
        ctx: &HostTensor,
        resid: &HostTensor,
        arena: &mut TensorArena,
    ) -> Result<HostTensor> {
        let name = format!("l{layer}.wo");
        self.touch(std::slice::from_ref(&name));
        assert_eq!(ctx.rows, resid.rows);
        let mut out = matmul_view(ctx.view(), self.weight(&name)?, self.cfg.hidden_size, arena);
        for (o, r) in out.data.iter_mut().zip(&resid.data) {
            *o += r;
        }
        Ok(out)
    }

    fn router(
        &mut self,
        layer: usize,
        x: &HostTensor,
        arena: &mut TensorArena,
    ) -> Result<(HostTensor, Vec<i32>, HostTensor)> {
        let c = self.cfg.clone();
        let (e, k) = (c.num_experts, c.top_k);
        let p = format!("l{layer}.");
        let names = vec![format!("{p}ln2"), format!("{p}wr")];
        self.touch(&names);

        let xn = rmsnorm_arena(x, self.weight(&names[0])?, c.rms_eps, arena);
        let logits = matmul_view(xn.view(), self.weight(&names[1])?, e, arena);
        let n = x.rows;
        let mut idx = Vec::with_capacity(n * k);
        let mut wts = arena.take_zeroed(n, k);
        // One scratch buffer for the softmax, reused across tokens and
        // calls — the top-k writes straight into `idx`/`wts`, so the loop
        // allocates nothing.
        let mut probs = std::mem::take(&mut self.probs_scratch);
        for t in 0..n {
            // softmax over experts
            let row = logits.row(t);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            probs.clear();
            probs.extend(row.iter().map(|&l| (l - max).exp()));
            let denom: f32 = probs.iter().sum();
            for pv in probs.iter_mut() {
                *pv /= denom;
            }
            // top-k by iterative argmax (stable first-max tie break, the
            // same contract as python's topk_by_argmax).
            let wrow = wts.row_mut(t);
            for r in 0..k {
                let mut best = 0usize;
                for j in 1..e {
                    if probs[j] > probs[best] {
                        best = j;
                    }
                }
                idx.push(best as i32);
                wrow[r] = probs[best];
                probs[best] = f32::NEG_INFINITY;
            }
            let sum: f32 = wrow.iter().sum();
            for w in wrow.iter_mut() {
                *w /= sum;
            }
        }
        self.probs_scratch = probs;
        arena.put(logits);
        Ok((xn, idx, wts))
    }

    fn expert_ffn(
        &mut self,
        layer: usize,
        sel: ExpertSel,
        x: TensorView<'_>,
        arena: &mut TensorArena,
    ) -> Result<HostTensor> {
        let p = self.expert_prefix(layer, sel);
        let names = vec![format!("{p}wg"), format!("{p}wu"), format!("{p}wd")];
        self.touch(&names);
        let inter = match sel {
            ExpertSel::Routed(_) => self.cfg.ffn_inter,
            ExpertSel::Shared => self.cfg.shared_inter,
        };
        let g = matmul_view(x, self.weight(&names[0])?, inter, arena);
        let u = matmul_view(x, self.weight(&names[1])?, inter, arena);
        let mut hmid = arena.take(x.rows, inter);
        for i in 0..g.data.len() {
            hmid.data[i] = silu(g.data[i]) * u.data[i];
        }
        let out = matmul_view(hmid.view(), self.weight(&names[2])?, self.cfg.hidden_size, arena);
        arena.put(g);
        arena.put(u);
        arena.put(hmid);
        Ok(out)
    }

    fn lm_head(&mut self, x: &HostTensor) -> Result<Vec<i32>> {
        let names = vec!["lnf".to_string(), "lm_head".to_string()];
        self.touch(&names);
        let xn = rmsnorm(x, self.weight("lnf")?, self.cfg.rms_eps);
        let logits = matmul(&xn, self.weight("lm_head")?, self.cfg.vocab_size);
        let mut out = Vec::with_capacity(x.rows);
        for t in 0..x.rows {
            let row = logits.row(t);
            let mut best = 0usize;
            for j in 1..row.len() {
                if row[j] > row[best] {
                    best = j;
                }
            }
            out.push(best as i32);
        }
        Ok(out)
    }

    fn take_uploaded_bytes(&mut self) -> usize {
        std::mem::take(&mut self.uploaded_bytes)
    }

    fn weights_total_bytes(&self) -> usize {
        self.total_bytes
    }

    fn cpu_attn_numerics(&self) -> Numerics {
        // The reference device path is plain f32 (see attn_decode), so the
        // consistent CPU mode is plain f32 too.
        Numerics::F32
    }
}

// ---------------------------------------------------------------------------
// Module math (mirrors python/compile/kernels/ref.py)
// ---------------------------------------------------------------------------

/// RMSNorm core: every element of `out` is overwritten.
fn rmsnorm_into(x: &[f32], rows: usize, dim: usize, g: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(dim, g.len());
    assert_eq!(out.len(), rows * dim);
    for t in 0..rows {
        let row = &x[t * dim..(t + 1) * dim];
        let mut ss = 0.0f32;
        for &v in row {
            ss += v * v;
        }
        let inv = 1.0 / (ss / dim as f32 + eps).sqrt();
        let o = &mut out[t * dim..(t + 1) * dim];
        for d in 0..row.len() {
            o[d] = row[d] * inv * g[d];
        }
    }
}

/// RMSNorm per row: `x * rsqrt(mean(x^2) + eps) * g`.
fn rmsnorm(x: &HostTensor, g: &[f32], eps: f32) -> HostTensor {
    let mut out = HostTensor::zeros(x.rows, x.dim);
    rmsnorm_into(&x.data, x.rows, x.dim, g, eps, &mut out.data);
    out
}

/// RMSNorm into an arena checkout. The output is fully overwritten, so
/// the uninit-content [`TensorArena::take`] is safe here.
fn rmsnorm_arena(x: &HostTensor, g: &[f32], eps: f32, arena: &mut TensorArena) -> HostTensor {
    let mut out = arena.take(x.rows, x.dim);
    rmsnorm_into(&x.data, x.rows, x.dim, g, eps, &mut out.data);
    out
}

/// Matmul core: accumulates `+=` into `out`, which must arrive zeroed.
fn matmul_into(x: &[f32], rows: usize, a: usize, w: &[f32], m: usize, out: &mut [f32]) {
    assert_eq!(w.len(), a * m, "weight shape mismatch: {} vs {a}x{m}", w.len());
    assert_eq!(x.len(), rows * a);
    assert_eq!(out.len(), rows * m);
    for t in 0..rows {
        let row = &x[t * a..(t + 1) * a];
        let o = &mut out[t * m..(t + 1) * m];
        for (i, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * m..(i + 1) * m];
            for j in 0..m {
                o[j] += xv * wrow[j];
            }
        }
    }
}

/// Row-major matmul: `x [n, a] @ w [a, m] -> [n, m]`.
fn matmul(x: &HostTensor, w: &[f32], m: usize) -> HostTensor {
    let mut out = HostTensor::zeros(x.rows, m);
    matmul_into(&x.data, x.rows, x.dim, w, m, &mut out.data);
    out
}

/// Matmul from a borrowed view into an arena checkout (the hot-path
/// variant: zero-copy input, recycled output). The accumulating core
/// requires a zeroed output, hence [`TensorArena::take_zeroed`].
fn matmul_view(x: TensorView<'_>, w: &[f32], m: usize, arena: &mut TensorArena) -> HostTensor {
    let mut out = arena.take_zeroed(x.rows, m);
    matmul_into(x.data, x.rows, x.dim, w, m, &mut out.data);
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotary embedding, rotate-half convention, applied in place per head.
/// `x` is `[n, heads*hd]`, `pos` the absolute position per row.
fn rope(x: &mut HostTensor, pos: &[i32], hd: usize, theta: f32) {
    let heads = x.dim / hd;
    let half = hd / 2;
    for t in 0..x.rows {
        let p = pos[t] as f32;
        let row = x.row_mut(t);
        for h in 0..heads {
            let o = &mut row[h * hd..(h + 1) * hd];
            for i in 0..half {
                let inv_freq = 1.0 / theta.powf(i as f32 / half as f32);
                let ang = p * inv_freq;
                let (sin, cos) = ang.sin_cos();
                let x1 = o[i];
                let x2 = o[i + half];
                o[i] = x1 * cos - x2 * sin;
                o[i + half] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// Deterministic weight init with the same shapes and scales as
/// `python/compile/model.py::init_weights` (values differ — different
/// RNG — but the *model* is fixed per seed).
fn gen_weights(cfg: &RtConfig, seed: u64) -> HashMap<String, Vec<f32>> {
    let mut rng = Rng::new(seed ^ 0x5EED_Fu64);
    let mut w = HashMap::new();
    fn nrm(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * scale).collect()
    }
    let (h, qd, kvd) = (cfg.hidden_size, cfg.q_dim(), cfg.kv_dim());
    w.insert("emb".into(), nrm(&mut rng, cfg.vocab_size * h, 0.1));
    for l in 0..cfg.num_layers {
        let p = format!("l{l}.");
        w.insert(format!("{p}ln1"), vec![1.0; h]);
        w.insert(format!("{p}wq"), nrm(&mut rng, h * qd, 0.05));
        w.insert(format!("{p}wk"), nrm(&mut rng, h * kvd, 0.05));
        w.insert(format!("{p}wv"), nrm(&mut rng, h * kvd, 0.05));
        w.insert(format!("{p}wo"), nrm(&mut rng, qd * h, 0.05));
        w.insert(format!("{p}ln2"), vec![1.0; h]);
        w.insert(format!("{p}wr"), nrm(&mut rng, h * cfg.num_experts, 0.5));
        for e in 0..cfg.num_experts {
            let q = format!("{p}e{e}.");
            w.insert(format!("{q}wg"), nrm(&mut rng, h * cfg.ffn_inter, 0.05));
            w.insert(format!("{q}wu"), nrm(&mut rng, h * cfg.ffn_inter, 0.05));
            w.insert(format!("{q}wd"), nrm(&mut rng, cfg.ffn_inter * h, 0.05));
        }
        if cfg.use_shared_expert {
            w.insert(format!("{p}se.wg"), nrm(&mut rng, h * cfg.shared_inter, 0.05));
            w.insert(format!("{p}se.wu"), nrm(&mut rng, h * cfg.shared_inter, 0.05));
            w.insert(format!("{p}se.wd"), nrm(&mut rng, cfg.shared_inter * h, 0.05));
        }
    }
    w.insert("lnf".into(), vec![1.0; h]);
    w.insert("lm_head".into(), nrm(&mut rng, h * cfg.vocab_size, 0.1));
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> RefBackend {
        RefBackend::new(RtConfig::tiny(), RefBackend::WEIGHT_SEED)
    }

    #[test]
    fn weights_deterministic_per_seed() {
        let a = backend();
        let b = backend();
        assert_eq!(a.weights["emb"], b.weights["emb"]);
        let c = RefBackend::new(RtConfig::tiny(), 7);
        assert_ne!(a.weights["emb"], c.weights["emb"]);
        assert!(a.total_bytes > 0);
    }

    #[test]
    fn embed_looks_up_rows() {
        let mut b = backend();
        let out = b.embed(&[3, 3, 5]).unwrap();
        assert_eq!(out.rows, 3);
        assert_eq!(out.row(0), out.row(1));
        assert_ne!(out.row(0), out.row(2));
        assert!(b.embed(&[512]).is_err(), "out-of-vocab id must error");
    }

    #[test]
    fn upload_accounting_charges_once() {
        let mut b = backend();
        let _ = b.embed(&[1]).unwrap();
        let first = b.take_uploaded_bytes();
        assert_eq!(first, 512 * 64 * 4, "emb upload = vocab*hidden*4");
        let _ = b.embed(&[2]).unwrap();
        assert_eq!(b.take_uploaded_bytes(), 0, "second touch is cached");
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = HostTensor::from_vec(vec![2.0; 8], 8);
        let g = vec![1.0; 8];
        let y = rmsnorm(&x, &g, 0.0);
        for &v in &y.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut x = HostTensor::from_vec((0..32).map(|i| (i as f32 * 0.3).sin()).collect(), 32);
        let orig = x.clone();
        rope(&mut x, &[0], 16, 10000.0);
        // pos 0: angle 0 -> identity.
        assert_eq!(x.data, orig.data);
        rope(&mut x, &[5], 16, 10000.0);
        let n0: f32 = orig.data.iter().map(|v| v * v).sum();
        let n1: f32 = x.data.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3, "rotation must preserve norm");
    }

    #[test]
    fn router_topk_distinct_normalized() {
        let mut b = backend();
        let x = HostTensor::from_vec(
            (0..3 * 64).map(|i| (i as f32 * 0.11).cos()).collect(),
            64,
        );
        let mut ar = TensorArena::new();
        let (xn, idx, wts) = b.router(0, &x, &mut ar).unwrap();
        assert_eq!(xn.rows, 3);
        assert_eq!(idx.len(), 6);
        for t in 0..3 {
            assert_ne!(idx[t * 2], idx[t * 2 + 1], "top-k must be distinct");
            let s: f32 = wts.row(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "weights renormalize to 1");
            assert!(wts.row(t)[0] >= wts.row(t)[1], "descending weights");
        }
    }

    #[test]
    fn router_output_independent_of_arena_state() {
        // The scratch-probs reuse and arena recycling must not leak state
        // between calls: a warm arena produces bit-identical routing.
        let mut b = backend();
        let x = HostTensor::from_vec(
            (0..5 * 64).map(|i| (i as f32 * 0.31).sin()).collect(),
            64,
        );
        let mut ar = TensorArena::new();
        let (xn1, idx1, wts1) = b.router(0, &x, &mut ar).unwrap();
        ar.put(xn1.clone());
        ar.put(wts1.clone());
        let (xn2, idx2, wts2) = b.router(0, &x, &mut ar).unwrap();
        assert_eq!(xn1.data, xn2.data);
        assert_eq!(idx1, idx2);
        assert_eq!(wts1.data, wts2.data);
        assert!(ar.stats().hits > 0, "warm call must recycle buffers");
    }

    #[test]
    fn attn_decode_single_token_returns_v() {
        let mut b = backend();
        let c = b.cfg().clone();
        let (qd, kvd, cap) = (c.q_dim(), c.kv_dim(), c.max_context);
        let q = HostTensor::from_vec(vec![0.3; qd], qd);
        let mut kw = HostTensor::zeros(1, cap * kvd);
        let mut vw = HostTensor::zeros(1, cap * kvd);
        for d in 0..kvd {
            kw.data[d] = 0.1;
            vw.data[d] = (d as f32) * 0.01;
        }
        let ctx = b.attn_decode(&q, &kw, &vw, &[1]).unwrap();
        // One key -> softmax weight 1 -> ctx head h = v row kv-head h/group.
        let group = c.num_heads / c.num_kv_heads;
        for h in 0..c.num_heads {
            let kvh = h / group;
            for d in 0..c.head_dim {
                let got = ctx.row(0)[h * c.head_dim + d];
                let want = vw.data[kvh * c.head_dim + d];
                assert!((got - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn attn_decode_len0_rows_are_zero() {
        let mut b = backend();
        let c = b.cfg().clone();
        let q = HostTensor::from_vec(vec![0.5; 2 * c.q_dim()], c.q_dim());
        let kw = HostTensor::zeros(2, c.max_context * c.kv_dim());
        let vw = kw.clone();
        let ctx = b.attn_decode(&q, &kw, &vw, &[0, 0]).unwrap();
        assert!(ctx.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn expert_ffn_row_independent() {
        // Padding rows must not change valid rows' outputs.
        let mut b = backend();
        let h = b.cfg().hidden_size;
        let row: Vec<f32> = (0..h).map(|i| (i as f32 * 0.17).sin()).collect();
        let x1 = HostTensor::from_vec(row.clone(), h);
        let mut padded = HostTensor::zeros(8, h);
        padded.row_mut(0).copy_from_slice(&row);
        let mut ar = TensorArena::new();
        let y1 = b.expert_ffn(0, ExpertSel::Routed(0), x1.view(), &mut ar).unwrap();
        let y8 = b.expert_ffn(0, ExpertSel::Routed(0), padded.view(), &mut ar).unwrap();
        assert_eq!(y1.row(0), y8.row(0));
        assert!(y8.row(3).iter().all(|&v| v == 0.0), "zero rows stay zero");
    }

    #[test]
    fn expert_ffn_steady_state_allocates_nothing() {
        // After one warm-up call per shape, every checkout the expert FFN
        // makes (g, u, hmid, out) must be an arena hit.
        let mut b = backend();
        let h = b.cfg().hidden_size;
        let x = HostTensor::from_vec((0..8 * h).map(|i| (i as f32 * 0.05).cos()).collect(), h);
        let mut ar = TensorArena::new();
        let y = b.expert_ffn(0, ExpertSel::Routed(1), x.view(), &mut ar).unwrap();
        ar.put(y);
        ar.reset_stats();
        let y = b.expert_ffn(0, ExpertSel::Routed(2), x.view(), &mut ar).unwrap();
        ar.put(y);
        let s = ar.stats();
        assert_eq!(s.misses, 0, "steady state must not allocate: {s:?}");
        assert_eq!(s.hits, 4, "g, u, hmid and the output recycle");
    }

    #[test]
    fn lm_head_is_deterministic_argmax() {
        let mut b = backend();
        let h = b.cfg().hidden_size;
        let x = HostTensor::from_vec((0..h).map(|i| (i as f32 * 0.07).sin()).collect(), h);
        let t1 = b.lm_head(&x).unwrap();
        let t2 = b.lm_head(&x).unwrap();
        assert_eq!(t1, t2);
        assert!(t1[0] >= 0 && (t1[0] as usize) < b.cfg().vocab_size);
    }
}
