//! CPU attention kernel — the rust analog of the paper's AVX GQA kernel
//! (paper §4.2 "CPU for self-attention", Appendix B "Numerical
//! Consistency of CPU Attention").
//!
//! Under the ω split, a fraction of the accumulated decode batch runs its
//! attention *mechanism* (QKᵀ → softmax → ·V) on CPU, reading K/V directly
//! from the host-resident cache — zero HtoD traffic for those sequences.
//! This is profitable because decode attention is GEMV-shaped (arithmetic
//! intensity ≈ 1): the CPU streams KV from DRAM at a pace comparable to
//! copying it over PCIe and computing on the GPU.
//!
//! Numerical consistency: the paper computes in FP32 but rounds to BF16
//! after each dot-product accumulation so CPU and GPU paths agree. The
//! same contract is implemented here (`Bf16Consistent` mode); tests verify
//! both modes against an oracle.
//!
//! Parallelism: sequences × query-heads are sharded across a scoped thread
//! pool (std threads; rayon unavailable offline).

use crate::exec::tensor::HostTensor;
use crate::util::round_bf16;

/// Numerics mode for the CPU kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Numerics {
    /// Plain FP32 accumulation.
    F32,
    /// FP32 accumulate, BF16 rounding after each dot product (paper App. B).
    Bf16Consistent,
}

/// One sequence's attention inputs for the CPU path.
pub struct SeqAttn<'a> {
    /// Query for this step: `num_heads * head_dim`.
    pub q: &'a [f32],
    /// K/V cache slices: `len * kv_heads * head_dim` (layout [pos][kvh][hd]).
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub len: usize,
}

/// Grouped-query attention for a batch of sequences; writes each result
/// (`num_heads * head_dim`) into `out` rows. Parallel over sequences.
pub fn decode_attention(
    seqs: &[SeqAttn<'_>],
    num_heads: usize,
    kv_heads: usize,
    head_dim: usize,
    numerics: Numerics,
    out: &mut [Vec<f32>],
    threads: usize,
) {
    assert_eq!(seqs.len(), out.len());
    // Thread-spawn costs ~tens of µs; below ~1M MACs the single-threaded
    // loop wins (measured in benches/hotpath.rs — EXPERIMENTS.md §Perf).
    let work: usize =
        seqs.iter().map(|s| s.len).sum::<usize>() * num_heads * head_dim;
    let nt = if work < 1_000_000 {
        1
    } else {
        threads.clamp(1, seqs.len().max(1))
    };
    if nt <= 1 || seqs.len() <= 1 {
        for (s, o) in seqs.iter().zip(out.iter_mut()) {
            attend_one(s, num_heads, kv_heads, head_dim, numerics, o);
        }
        return;
    }
    // Shard sequences across scoped threads.
    let chunks: Vec<(usize, &[SeqAttn<'_>], &mut [Vec<f32>])> = {
        let mut res = Vec::new();
        let per = seqs.len().div_ceil(nt);
        let mut s_rest = seqs;
        let mut o_rest = out;
        let mut base = 0;
        while !s_rest.is_empty() {
            let take = per.min(s_rest.len());
            let (s_now, s_next) = s_rest.split_at(take);
            let (o_now, o_next) = o_rest.split_at_mut(take);
            res.push((base, s_now, o_now));
            s_rest = s_next;
            o_rest = o_next;
            base += take;
        }
        res
    };
    std::thread::scope(|scope| {
        for (_base, s_chunk, o_chunk) in chunks {
            scope.spawn(move || {
                for (s, o) in s_chunk.iter().zip(o_chunk.iter_mut()) {
                    attend_one(s, num_heads, kv_heads, head_dim, numerics, o);
                }
            });
        }
    });
}

/// Typed wrapper over [`decode_attention`]: returns the batch's contexts
/// as one `[b, num_heads*head_dim]` tensor in sequence order (what the
/// pipeline's attention accumulator consumes).
pub fn decode_attention_t(
    seqs: &[SeqAttn<'_>],
    num_heads: usize,
    kv_heads: usize,
    head_dim: usize,
    numerics: Numerics,
    threads: usize,
) -> HostTensor {
    let mut out = vec![Vec::new(); seqs.len()];
    decode_attention(seqs, num_heads, kv_heads, head_dim, numerics, &mut out, threads);
    let mut t = HostTensor::empty(num_heads * head_dim);
    for o in &out {
        t.push_rows(o);
    }
    t
}

/// Attention for one sequence, all query heads.
fn attend_one(
    s: &SeqAttn<'_>,
    num_heads: usize,
    kv_heads: usize,
    head_dim: usize,
    numerics: Numerics,
    out: &mut Vec<f32>,
) {
    let group = num_heads / kv_heads;
    let kvd = kv_heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    out.clear();
    out.resize(num_heads * head_dim, 0.0);
    let mut scores = vec![0.0f32; s.len];
    for h in 0..num_heads {
        let kvh = h / group;
        let q = &s.q[h * head_dim..(h + 1) * head_dim];
        // scores[t] = <q, k_t> * scale
        let mut max = f32::NEG_INFINITY;
        for t in 0..s.len {
            let k = &s.k[t * kvd + kvh * head_dim..t * kvd + (kvh + 1) * head_dim];
            let mut acc = 0.0f32;
            for d in 0..head_dim {
                acc += q[d] * k[d];
            }
            if numerics == Numerics::Bf16Consistent {
                acc = round_bf16(acc);
            }
            let sc = acc * scale;
            scores[t] = sc;
            max = max.max(sc);
        }
        // softmax
        let mut denom = 0.0f32;
        for t in 0..s.len {
            let e = (scores[t] - max).exp();
            scores[t] = e;
            denom += e;
        }
        let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        // out_h = sum_t p_t * v_t
        let o = &mut out[h * head_dim..(h + 1) * head_dim];
        for t in 0..s.len {
            let p = scores[t] * inv;
            let v = &s.v[t * kvd + kvh * head_dim..t * kvd + (kvh + 1) * head_dim];
            for d in 0..head_dim {
                o[d] += p * v[d];
            }
        }
        if numerics == Numerics::Bf16Consistent {
            for d in 0..head_dim {
                o[d] = round_bf16(o[d]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    /// Straight-line oracle (no blocking, no bf16): full-precision GQA.
    fn oracle(s: &SeqAttn<'_>, nh: usize, nkv: usize, hd: usize) -> Vec<f32> {
        let group = nh / nkv;
        let kvd = nkv * hd;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = vec![0.0f32; nh * hd];
        for h in 0..nh {
            let kvh = h / group;
            let q = &s.q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..s.len)
                .map(|t| {
                    let k = &s.k[t * kvd + kvh * hd..t * kvd + (kvh + 1) * hd];
                    q.iter().zip(k).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|x| (x - max).exp()).collect();
            let denom: f32 = exps.iter().sum();
            for t in 0..s.len {
                let p = exps[t] / denom;
                let v = &s.v[t * kvd + kvh * hd..t * kvd + (kvh + 1) * hd];
                for d in 0..hd {
                    out[h * hd + d] += p * v[d];
                }
            }
        }
        out
    }

    fn rand_seq(rng: &mut Rng, len: usize, nh: usize, nkv: usize, hd: usize)
        -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            rng.normal_vec(nh * hd),
            rng.normal_vec(len * nkv * hd),
            rng.normal_vec(len * nkv * hd),
        )
    }

    #[test]
    fn matches_oracle_f32() {
        let mut rng = Rng::new(0);
        let (nh, nkv, hd) = (4, 2, 16);
        let (q, k, v) = rand_seq(&mut rng, 37, nh, nkv, hd);
        let seqs = [SeqAttn { q: &q, k: &k, v: &v, len: 37 }];
        let mut out = vec![Vec::new()];
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut out, 1);
        let want = oracle(&seqs[0], nh, nkv, hd);
        for (a, b) in out[0].iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn bf16_mode_close_to_f32() {
        let mut rng = Rng::new(1);
        let (nh, nkv, hd) = (4, 4, 8);
        let (q, k, v) = rand_seq(&mut rng, 50, nh, nkv, hd);
        let seqs = [SeqAttn { q: &q, k: &k, v: &v, len: 50 }];
        let mut o32 = vec![Vec::new()];
        let mut obf = vec![Vec::new()];
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut o32, 1);
        decode_attention(&seqs, nh, nkv, hd, Numerics::Bf16Consistent, &mut obf, 1);
        for (a, b) in o32[0].iter().zip(&obf[0]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        // And the bf16 outputs are exactly bf16-representable.
        for &x in &obf[0] {
            assert_eq!(x, crate::util::round_bf16(x));
        }
    }

    #[test]
    fn threaded_matches_single_thread() {
        let mut rng = Rng::new(2);
        let (nh, nkv, hd) = (8, 2, 16);
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> = (0..13)
            .map(|_| {
                let len = rng.range(1, 64);
                let (q, k, v) = rand_seq(&mut rng, len, nh, nkv, hd);
                (q, k, v, len)
            })
            .collect();
        let seqs: Vec<SeqAttn<'_>> = data
            .iter()
            .map(|(q, k, v, len)| SeqAttn { q, k, v, len: *len })
            .collect();
        let mut a = vec![Vec::new(); seqs.len()];
        let mut b = vec![Vec::new(); seqs.len()];
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut a, 1);
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut b, 6);
        assert_eq!(a, b, "thread count must not change results");
    }

    #[test]
    fn typed_wrapper_matches_vec_api() {
        let mut rng = Rng::new(9);
        let (nh, nkv, hd) = (4, 2, 8);
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> = (0..5)
            .map(|_| {
                let len = rng.range(1, 32);
                let (q, k, v) = rand_seq(&mut rng, len, nh, nkv, hd);
                (q, k, v, len)
            })
            .collect();
        let seqs: Vec<SeqAttn<'_>> = data
            .iter()
            .map(|(q, k, v, len)| SeqAttn { q, k, v, len: *len })
            .collect();
        let mut out = vec![Vec::new(); seqs.len()];
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut out, 1);
        let t = decode_attention_t(&seqs, nh, nkv, hd, Numerics::F32, 1);
        assert_eq!(t.rows, seqs.len());
        assert_eq!(t.dim, nh * hd);
        for (i, o) in out.iter().enumerate() {
            assert_eq!(t.row(i), &o[..]);
        }
    }

    #[test]
    fn single_token_context_returns_v() {
        // len=1: softmax over one score = 1 -> output == v row per head.
        let mut rng = Rng::new(3);
        let (nh, nkv, hd) = (4, 2, 8);
        let (q, k, v) = rand_seq(&mut rng, 1, nh, nkv, hd);
        let seqs = [SeqAttn { q: &q, k: &k, v: &v, len: 1 }];
        let mut out = vec![Vec::new()];
        decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut out, 1);
        let group = nh / nkv;
        for h in 0..nh {
            let kvh = h / group;
            for d in 0..hd {
                assert!((out[0][h * hd + d] - v[kvh * hd + d]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn prop_output_within_v_convex_hull() {
        // Attention output is a convex combination of V rows: each output
        // coordinate must lie within [min_t v, max_t v] per (head, dim).
        prop_check(50, |rng: &mut Rng| {
            let (nh, nkv, hd) = (4, 2, 8);
            let len = rng.range(1, 32);
            let (q, k, v) = rand_seq(rng, len, nh, nkv, hd);
            let seqs = [SeqAttn { q: &q, k: &k, v: &v, len }];
            let mut out = vec![Vec::new()];
            decode_attention(&seqs, nh, nkv, hd, Numerics::F32, &mut out, 1);
            let group = nh / nkv;
            let kvd = nkv * hd;
            for h in 0..nh {
                let kvh = h / group;
                for d in 0..hd {
                    let col: Vec<f32> =
                        (0..len).map(|t| v[t * kvd + kvh * hd + d]).collect();
                    let lo = col.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let x = out[0][h * hd + d];
                    assert!(
                        x >= lo - 1e-4 && x <= hi + 1e-4,
                        "h={h} d={d}: {x} outside [{lo}, {hi}]"
                    );
                }
            }
        });
    }
}
