//! Live baseline batching policies over the same engine substrate.
//!
//! The paper's throughput tables compare module-based batching against
//! model-based batching (DeepSpeed/FlexGen-style unified batches) and
//! continuous batching (vLLM-style sequence-level scheduling with prefill
//! insertion). These runners drive the *identical* runtime, KV manager and
//! module wrappers — only the batching policy differs, so live A/B
//! comparisons (examples/offline_benchmark.rs) isolate exactly the paper's
//! variable. Greedy decode is policy-invariant, so all runners must emit
//! identical tokens (asserted in integration tests).

use std::sync::Arc;

use anyhow::Result;

use crate::engine::{BatchState, Engine};

/// Model-based batching: a unified micro-batch walks the entire model;
/// experts see only that micro-batch's tokens (paper Fig. 2 left).
pub fn run_model_based(
    eng: &mut Engine,
    prompts: &[Vec<i32>],
    steps: usize,
    micro_batch: usize,
) -> Result<Vec<Vec<i32>>> {
    let mut out = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(micro_batch.max(1)) {
        let (mut state, first) = eng.prefill(chunk)?;
        let mut toks: Vec<Vec<i32>> = first.iter().map(|&t| vec![t]).collect();
        for _ in 0..steps - 1 {
            let next = eng.decode_step(&mut state)?;
            for (i, &t) in next.iter().enumerate() {
                toks[i].push(t);
            }
        }
        let bytes = state.kv.read().unwrap().host_bytes();
        eng.host_pool.free(bytes);
        out.extend(toks);
    }
    Ok(out)
}

/// Continuous batching (vLLM-style): a slot pool; whenever a slot frees,
/// the next pending prompt is prefilled *individually* (batch-1 insertion
/// — the TTFT-optimizing behaviour the paper highlights) and joins the
/// decode set; every step decodes whatever is active.
pub struct ContinuousRunner {
    pub max_slots: usize,
}

impl ContinuousRunner {
    pub fn new(max_slots: usize) -> Self {
        ContinuousRunner { max_slots }
    }

    pub fn run(
        &self,
        eng: &mut Engine,
        prompts: &[Vec<i32>],
        steps: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let kv = eng.alloc_kv_pool(self.max_slots)?;

        let mut results: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut next_prompt = 0usize;
        // Active set: (prompt index, slot, len, last token).
        let mut active: Vec<(usize, usize, usize, i32)> = Vec::new();
        let mut finished = 0usize;

        while finished < prompts.len() {
            // Insert prefills one at a time while slots are free.
            while next_prompt < prompts.len() && active.len() < self.max_slots {
                let idx = next_prompt;
                next_prompt += 1;
                let (slots, lens, first) =
                    eng.prefill_into(&kv, std::slice::from_ref(&prompts[idx]))?;
                results[idx].push(first[0]);
                if steps == 1 {
                    kv.write().unwrap().free_slot(slots[0]);
                    finished += 1;
                } else {
                    active.push((idx, slots[0], lens[0], first[0]));
                }
            }
            if active.is_empty() {
                break;
            }
            // One decode step over the current active set.
            let mut state = BatchState {
                kv: Arc::clone(&kv),
                slots: active.iter().map(|a| a.1).collect(),
                lens: active.iter().map(|a| a.2).collect(),
                last: active.iter().map(|a| a.3).collect(),
            };
            let next = eng.decode_step(&mut state)?;
            // Sync back; retire sequences that reached their budget.
            let mut still = Vec::with_capacity(active.len());
            for (i, (idx, slot, _, _)) in active.iter().cloned().enumerate() {
                results[idx].push(next[i]);
                if results[idx].len() >= steps {
                    kv.write().unwrap().free_slot(slot);
                    finished += 1;
                } else {
                    still.push((idx, slot, state.lens[i], next[i]));
                }
            }
            active = still;
        }
        eng.free_kv_pool(&kv);
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    // Live-policy agreement tests need artifacts; they live in
    // rust/tests/integration_engine.rs. Here: pure logic checks.

    #[test]
    fn continuous_runner_constructs() {
        let r = super::ContinuousRunner::new(8);
        assert_eq!(r.max_slots, 8);
    }
}
