//! Integration: AOT HLO artifacts execute correctly on the PJRT runtime.
//!
//! Every module's golden input/output pair (produced by python in
//! `artifacts/golden.npz` with `jax.jit` on the same XLA CPU backend) must
//! reproduce through the rust loader bit-for-bit (tolerance covers only
//! run-to-run nondeterminism, which XLA CPU does not exhibit).
//!
//! Requires `make artifacts`; tests panic with a clear message otherwise.

use std::collections::HashMap;

use xla::FromRawBytes;

use moe_gen::runtime::{to_f32, to_i32, Artifacts, Runtime};

fn art_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load_golden() -> HashMap<String, xla::Literal> {
    let path = art_dir().join("golden.npz");
    xla::Literal::read_npz(&path, &())
        .expect("golden.npz missing — run `make artifacts`")
        .into_iter()
        .collect()
}

fn runtime() -> Runtime {
    Runtime::new(art_dir()).expect("artifacts missing — run `make artifacts`")
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let d = (a - b).abs();
        assert!(
            d <= tol * (1.0 + b.abs()),
            "{what}[{i}]: {a} vs {b} (|d|={d})"
        );
    }
}

/// Run one module's golden pair through the rust runtime.
fn check_module(rt: &Runtime, golden: &HashMap<String, xla::Literal>, name: &str) {
    // Collect g.<name>.in0..inN in order.
    let mut args: Vec<&xla::Literal> = Vec::new();
    for i in 0.. {
        match golden.get(&format!("g.{name}.in{i}")) {
            Some(l) => args.push(l),
            None => break,
        }
    }
    assert!(!args.is_empty(), "no golden inputs for {name}");
    // Goldens were generated at each module's smallest bucket; find the
    // variant whose parameter shapes match the golden input shapes.
    let spec = {
        let arts = &rt.artifacts;
        let shapes: Vec<Vec<usize>> = args
            .iter()
            .map(|l| {
                l.array_shape()
                    .unwrap()
                    .dims()
                    .iter()
                    .map(|&d| d as usize)
                    .collect()
            })
            .collect();
        arts.buckets(name)
            .iter()
            .map(|&b| arts.variant(name, b).unwrap().clone())
            .find(|s| s.param_shapes == shapes)
            .unwrap_or_else(|| panic!("{name}: no variant matches golden shapes {shapes:?}"))
    };
    let outs = rt.execute(&spec, &args).unwrap_or_else(|e| panic!("{name}: {e}"));
    for (i, out) in outs.iter().enumerate() {
        let want = &golden[&format!("g.{name}.out{i}")];
        match out.ty().unwrap() {
            xla::ElementType::S32 => {
                assert_eq!(
                    to_i32(out).unwrap(),
                    to_i32(want).unwrap(),
                    "{name} out{i} (i32)"
                );
            }
            _ => {
                assert_close(
                    &to_f32(out).unwrap(),
                    &to_f32(want).unwrap(),
                    1e-5,
                    &format!("{name} out{i}"),
                );
            }
        }
    }
}

#[test]
fn manifest_loads_with_all_modules() {
    let arts = Artifacts::load(art_dir()).unwrap();
    let mut names = arts.module_names();
    names.sort();
    for m in [
        "attn_decode", "attn_prefill", "embed", "expert_ffn", "lm_head",
        "post_attention", "pre_attention", "router",
    ] {
        assert!(names.contains(&m), "manifest missing {m}");
    }
    assert_eq!(arts.cfg.hidden_size, 64);
    // Bucket resolution: smallest >= rows.
    assert_eq!(arts.variant("expert_ffn", 1).unwrap().bucket, 8);
    assert_eq!(arts.variant("expert_ffn", 9).unwrap().bucket, 32);
    assert!(arts.variant("expert_ffn", 100_000).is_err());
}

#[test]
fn weights_load_and_have_expected_sizes() {
    let rt = runtime();
    let c = rt.cfg().clone();
    let emb = rt.weights.get("emb").unwrap();
    assert_eq!(emb.element_count(), c.vocab_size * c.hidden_size);
    for layer in 0..c.num_layers {
        for e in 0..c.num_experts {
            let wg = rt.weights.get(&format!("l{layer}.e{e}.wg")).unwrap();
            assert_eq!(wg.element_count(), c.hidden_size * c.ffn_inter);
        }
    }
    assert!(rt.weights.total_bytes > 0);
}

#[test]
fn golden_embed() {
    check_module(&runtime(), &load_golden(), "embed");
}

#[test]
fn golden_pre_attention() {
    check_module(&runtime(), &load_golden(), "pre_attention");
}

#[test]
fn golden_attn_prefill() {
    check_module(&runtime(), &load_golden(), "attn_prefill");
}

#[test]
fn golden_attn_decode() {
    check_module(&runtime(), &load_golden(), "attn_decode");
}

#[test]
fn golden_post_attention() {
    check_module(&runtime(), &load_golden(), "post_attention");
}

#[test]
fn golden_router() {
    check_module(&runtime(), &load_golden(), "router");
}

#[test]
fn golden_expert_ffn() {
    check_module(&runtime(), &load_golden(), "expert_ffn");
}

#[test]
fn golden_lm_head() {
    check_module(&runtime(), &load_golden(), "lm_head");
}

#[test]
fn executable_cache_compiles_once() {
    let rt = runtime();
    let spec = rt.artifacts.variant("expert_ffn", 8).unwrap().clone();
    let _ = rt.executable(&spec).unwrap();
    let t_first = *rt.compile_secs.borrow();
    let _ = rt.executable(&spec).unwrap();
    assert_eq!(
        *rt.compile_secs.borrow(),
        t_first,
        "second lookup must hit the cache"
    );
}

#[test]
fn warmup_compiles_all_buckets() {
    let rt = runtime();
    rt.warmup(&["expert_ffn", "attn_decode"]).unwrap();
    assert!(*rt.compile_secs.borrow() > 0.0);
}

#[test]
fn expert_ffn_all_buckets_row_consistent() {
    // The same token row must produce the same output at every bucket
    // size (padding must not leak into valid rows).
    let rt = runtime();
    let c = rt.cfg().clone();
    let h = c.hidden_size;
    let row: Vec<f32> = (0..h).map(|i| (i as f32 * 0.17).sin()).collect();
    let wg = rt.weights.get("l0.e0.wg").unwrap();
    let wu = rt.weights.get("l0.e0.wu").unwrap();
    let wd = rt.weights.get("l0.e0.wd").unwrap();
    let mut ref_out: Option<Vec<f32>> = None;
    for &b in &c.expert_buckets {
        let mut x = vec![0.0f32; b * h];
        x[..h].copy_from_slice(&row);
        let x_l = moe_gen::runtime::lit_f32(&x, &[b, h]).unwrap();
        let spec = rt.artifacts.variant("expert_ffn", b).unwrap().clone();
        let outs = rt
            .execute(&spec, &[wg.as_ref(), wu.as_ref(), wd.as_ref(), &x_l])
            .unwrap();
        let y = to_f32(&outs[0]).unwrap()[..h].to_vec();
        if let Some(r) = &ref_out {
            assert_close(&y, r, 1e-5, &format!("bucket {b}"));
        } else {
            ref_out = Some(y);
        }
    }
}
