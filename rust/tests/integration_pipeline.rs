//! Integration: the strategy-driven module pipeline against a monolithic
//! per-sequence reference loop on the same backend.
//!
//! The tentpole claim of the `exec` refactor: the *batching schedule* —
//! accumulated batch `B`, attention micro-batch `b_a`, expert micro-batch
//! `b_e`, CPU-attention split ω, bucket padding — is throughput-only.
//! Greedy tokens must be bit-identical between
//!
//! * the pipeline under any plan (including one searched by
//!   `sched::search_decode` for a paper-scale scenario), and
//! * a monolithic reference that walks each sequence alone through the
//!   backend's modules with no padding, no accumulation and no
//!   micro-batching (the shape of `python/compile/engine_ref.py`).
//!
//! Everything here runs hermetically on the reference backend — no
//! artifacts, no PJRT.

use moe_gen::batching::{micro_batches, GroupedBatch};
use moe_gen::config::EngineConfig;
use moe_gen::engine::Engine;
use moe_gen::exec::{ExpertSel, HostTensor, ModuleKind, Plan, TensorArena};
use moe_gen::hw;
use moe_gen::model;
use moe_gen::runtime::{Backend, RefBackend, RtConfig};
use moe_gen::sched::{self, Knobs, Scenario};
use moe_gen::util::pick_bucket;
use moe_gen::workload;

fn ref_engine(cfg: EngineConfig) -> Engine {
    let backend = Box::new(RefBackend::new(RtConfig::tiny(), RefBackend::WEIGHT_SEED));
    Engine::with_backend(cfg, backend).unwrap()
}

fn prompts() -> Vec<Vec<i32>> {
    workload::generate_prompts(6, 12, 40, 512, 3)
}

// ---------------------------------------------------------------------------
// Monolithic reference: one sequence at a time, modules called directly,
// no padding, no micro-batching, KV as plain per-layer tensors.
// ---------------------------------------------------------------------------

struct RefMonolith {
    be: RefBackend,
    ar: TensorArena,
}

impl RefMonolith {
    fn new() -> Self {
        Self::with_cfg(RtConfig::tiny())
    }

    fn with_cfg(cfg: RtConfig) -> Self {
        RefMonolith {
            be: RefBackend::new(cfg, RefBackend::WEIGHT_SEED),
            ar: TensorArena::new(),
        }
    }

    fn moe(&mut self, layer: usize, x: HostTensor) -> HostTensor {
        let c = self.be.cfg().clone();
        let (xn, idx, wts) = self.be.router(layer, &x, &mut self.ar).unwrap();
        let n = x.rows;
        let mut acc = HostTensor::zeros(n, c.hidden_size);
        for e in 0..c.num_experts {
            let mut rows = Vec::new();
            let mut ws = Vec::new();
            for t in 0..n {
                for r in 0..c.top_k {
                    if idx[t * c.top_k + r] == e as i32 {
                        rows.push(t);
                        ws.push(wts.row(t)[r]);
                    }
                }
            }
            if rows.is_empty() {
                continue;
            }
            let gathered = xn.gather(&rows, rows.len());
            let y = self
                .be
                .expert_ffn(layer, ExpertSel::Routed(e), gathered.view(), &mut self.ar)
                .unwrap();
            acc.scatter_add(&rows, &ws, &y);
        }
        if c.use_shared_expert {
            let ys = self
                .be
                .expert_ffn(layer, ExpertSel::Shared, xn.view(), &mut self.ar)
                .unwrap();
            acc.add_assign(&ys);
        }
        let mut out = x;
        out.add_assign(&acc);
        out
    }

    /// Prefill one prompt; returns per-layer (k, v) caches and the first
    /// generated token.
    fn prefill(&mut self, p: &[i32]) -> (Vec<(HostTensor, HostTensor)>, i32) {
        let c = self.be.cfg().clone();
        let len = p.len();
        let pos: Vec<i32> = (0..len as i32).collect();
        let mut x = self.be.embed(p).unwrap();
        let mut caches = Vec::new();
        for layer in 0..c.num_layers {
            let (q, k, v) = self.be.pre_attention(layer, &x, &pos, &mut self.ar).unwrap();
            let qp = HostTensor::from_vec(q.data.clone(), len * c.q_dim());
            let kp = HostTensor::from_vec(k.data.clone(), len * c.kv_dim());
            let vp = HostTensor::from_vec(v.data.clone(), len * c.kv_dim());
            let ctx = self.be.attn_prefill(&qp, &kp, &vp, &[len as i32], len).unwrap();
            let ctx = HostTensor::from_vec(ctx.data, c.q_dim());
            caches.push((k, v));
            x = self.be.post_attention(layer, &ctx, &x, &mut self.ar).unwrap();
            x = self.moe(layer, x);
        }
        let last = HostTensor::from_vec(x.row(len - 1).to_vec(), c.hidden_size);
        let tok = self.be.lm_head(&last).unwrap()[0];
        (caches, tok)
    }

    /// One decode step for one sequence (`cur_len` tokens cached).
    fn decode_step(
        &mut self,
        caches: &mut [(HostTensor, HostTensor)],
        cur_len: usize,
        last: i32,
    ) -> i32 {
        let c = self.be.cfg().clone();
        let kvd = c.kv_dim();
        let pos = vec![cur_len as i32];
        let mut x = self.be.embed(&[last]).unwrap();
        for layer in 0..c.num_layers {
            let (q, k, v) = self.be.pre_attention(layer, &x, &pos, &mut self.ar).unwrap();
            caches[layer].0.extend(&k);
            caches[layer].1.extend(&v);
            let n_len = cur_len + 1;
            let mut kw = HostTensor::zeros(1, c.max_context * kvd);
            kw.data[..n_len * kvd].copy_from_slice(&caches[layer].0.data);
            let mut vw = HostTensor::zeros(1, c.max_context * kvd);
            vw.data[..n_len * kvd].copy_from_slice(&caches[layer].1.data);
            let ctx = self.be.attn_decode(&q, &kw, &vw, &[n_len as i32]).unwrap();
            x = self.be.post_attention(layer, &ctx, &x, &mut self.ar).unwrap();
            x = self.moe(layer, x);
        }
        self.be.lm_head(&x).unwrap()[0]
    }

    fn generate(&mut self, prompts: &[Vec<i32>], steps: usize) -> Vec<Vec<i32>> {
        prompts
            .iter()
            .map(|p| {
                let (mut caches, first) = self.prefill(p);
                let mut toks = vec![first];
                let mut len = p.len();
                for _ in 0..steps - 1 {
                    let t = self.decode_step(&mut caches, len, *toks.last().unwrap());
                    toks.push(t);
                    len += 1;
                }
                toks
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn pipeline_matches_monolithic_reference() {
    let steps = 6;
    let want = RefMonolith::new().generate(&prompts(), steps);
    let mut eng = ref_engine(EngineConfig::default());
    let got = eng.generate(&prompts(), steps).unwrap();
    assert_eq!(got, want, "pipeline diverged from the monolithic reference");
}

#[test]
fn grouped_micro_batched_expert_phase_matches_plain_gather() {
    // The grouped hot path (counting-sort permute → contiguous per-expert
    // segments → bucket-padded micro-batches → weighted unpermute-scatter)
    // must be bit-identical to the pre-grouped per-group gather/scatter
    // formulation, for both a whole-segment micro-batch and a tiny one
    // that forces many partial-bucket pads.
    let mut be = RefBackend::new(RtConfig::tiny(), RefBackend::WEIGHT_SEED);
    let mut ar = TensorArena::new();
    let c = be.cfg().clone();
    let (h, k, ne) = (c.hidden_size, c.top_k, c.num_experts);
    let n = 37; // odd, off-bucket: every segment ends in a partial chunk
    let mut rng = moe_gen::util::rng::Rng::new(9);
    let x = HostTensor::from_vec(rng.normal_vec(n * h), h);
    let (xn, idx, wts) = be.router(0, &x, &mut ar).unwrap();

    // Legacy formulation: per-expert row lists, unpadded gathers.
    let mut want = HostTensor::zeros(n, h);
    for e in 0..ne {
        let mut rows = Vec::new();
        let mut ws = Vec::new();
        for t in 0..n {
            for r in 0..k {
                if idx[t * k + r] == e as i32 {
                    rows.push(t);
                    ws.push(wts.row(t)[r]);
                }
            }
        }
        if rows.is_empty() {
            continue;
        }
        let gathered = xn.gather(&rows, rows.len());
        let y = be.expert_ffn(0, ExpertSel::Routed(e), gathered.view(), &mut ar).unwrap();
        want.scatter_add(&rows, &ws, &y);
    }

    for micro in [512usize, 8] {
        let g = GroupedBatch::build(&idx, &wts.data, n, k, ne);
        let mut sorted = HostTensor::zeros(n * k, h);
        for (slot, &t) in g.perm.iter().enumerate() {
            sorted.row_mut(slot).copy_from_slice(xn.row(t));
        }
        let mut got = HostTensor::zeros(n, h);
        for e in 0..ne {
            let seg = g.segment(e);
            if seg.is_empty() {
                continue;
            }
            for r in micro_batches(seg.len(), micro) {
                let abs = seg.start + r.start..seg.start + r.end;
                let rows = &g.perm[abs.clone()];
                let ws = &g.weights[abs.clone()];
                let bucket = pick_bucket(rows.len(), &c.expert_buckets).unwrap();
                let y = if bucket == rows.len() {
                    be.expert_ffn(0, ExpertSel::Routed(e), sorted.view_rows(abs.clone()), &mut ar)
                        .unwrap()
                } else {
                    let mut pad = HostTensor::zeros(bucket, h);
                    pad.data[..rows.len() * h].copy_from_slice(sorted.rows_slice(abs.clone()));
                    be.expert_ffn(0, ExpertSel::Routed(e), pad.view(), &mut ar).unwrap()
                };
                got.scatter_add(rows, ws, &y);
            }
        }
        assert_eq!(got.data, want.data, "grouped expert phase diverged at micro={micro}");
    }
}

#[test]
fn grouped_pipeline_matches_reference_without_shared_expert() {
    // The shared-expert branch off: the grouped path's routed-expert loop
    // alone must still reproduce the monolithic reference bit-for-bit.
    let cfg = RtConfig { use_shared_expert: false, ..RtConfig::tiny() };
    let steps = 4;
    let want = RefMonolith::with_cfg(cfg.clone()).generate(&prompts(), steps);
    let backend = Box::new(RefBackend::new(cfg, RefBackend::WEIGHT_SEED));
    let mut eng = Engine::with_backend(EngineConfig::default(), backend).unwrap();
    let got = eng.generate(&prompts(), steps).unwrap();
    assert_eq!(got, want, "shared-expert-free pipeline diverged from the reference");
}

#[test]
fn steady_state_decode_reuses_arena_buffers() {
    // Acceptance: after a warm-up run populates the scratch arena, a
    // repeat of the same workload checks (nearly) every bucket-shaped
    // tensor out of the pool — no fresh heap allocations in the expert
    // and projection hot paths.
    let mut eng = ref_engine(EngineConfig::default());
    let _ = eng.generate(&prompts(), 4).unwrap();
    assert!(eng.metrics.arena.recycled_bytes > 0, "warm-up never recycled a buffer");
    eng.reset_accounting(); // counters reset; pooled buffers stay warm
    let _ = eng.generate(&prompts(), 4).unwrap();
    let rate = eng.metrics.arena_hit_rate();
    assert!(rate >= 0.9, "steady-state arena hit rate {rate} below 0.9");
}

#[test]
fn searched_strategy_executes_through_pipeline_with_identical_tokens() {
    // The acceptance loop: a strategy searched for a *paper-scale*
    // scenario is directly executable by the engine — its (B, b_a, b_e, ω)
    // become the pipeline's micro-batch plan (clamped to the tiny model's
    // bucket grid at launch) — and tokens match the monolithic reference.
    let scn = Scenario::new(model::mixtral_8x7b(), hw::c2(), 512, 256);
    let dec = sched::search_decode(&scn, &Knobs::moe_gen());
    let pre = sched::search_prefill(&scn, &Knobs::moe_gen_gpu_only());
    assert!(dec.throughput > 0.0);

    let mut eng = ref_engine(EngineConfig::default());
    eng.set_strategy(&dec.strategy, Some(&pre.strategy));
    let plan = eng.plan();
    assert_eq!(plan.attn_micro, dec.strategy.b_a, "plan must source b_a from the strategy");
    assert_eq!(plan.expert_micro, dec.strategy.b_e, "plan must source b_e from the strategy");
    assert_eq!(plan.omega, dec.strategy.omega, "plan must source omega from the strategy");

    let steps = 5;
    let got = eng.generate(&prompts(), steps).unwrap();
    let want = RefMonolith::new().generate(&prompts(), steps);
    assert_eq!(got, want, "searched strategy changed greedy tokens");
    // The searched omega for Mixtral-on-C2 is interior (paper Table 10),
    // so both attention paths must actually have run.
    if plan.omega > 0.0 {
        assert!(eng.metrics.cpu_attn_seqs > 0, "ω > 0 but CPU attention never ran");
    }
    assert!(eng.metrics.gpu_attn_seqs > 0 || plan.omega >= 1.0);
}

#[test]
fn extreme_plans_are_throughput_only() {
    // Small prompt set: the b_e = 1 plan launches one expert call per
    // (token, rank) assignment, which is the point — but keep it cheap.
    let ps: Vec<Vec<i32>> = prompts().into_iter().take(4).collect();
    let steps = 3;
    let want = RefMonolith::new().generate(&ps, steps);
    let plans = [
        // One-sequence attention launches, one-token expert launches.
        Plan { accum_batch: 128, attn_micro: 1, prefill_attn_micro: 1, expert_micro: 1,
               omega: 0.0, prefetch_bytes: None, cache_bytes: None, reuse: 1.0, replication_bytes: None },
        // Everything on the CPU attention path.
        Plan { accum_batch: 128, attn_micro: 8, prefill_attn_micro: 16, expert_micro: 512,
               omega: 1.0, prefetch_bytes: None, cache_bytes: None, reuse: 1.0, replication_bytes: None },
        // Tiny accumulated batch: three separate prefill/decode waves.
        Plan { accum_batch: 2, attn_micro: 8, prefill_attn_micro: 16, expert_micro: 512,
               omega: 0.5, prefetch_bytes: None, cache_bytes: None, reuse: 1.0, replication_bytes: None },
    ];
    for plan in plans {
        let mut eng = ref_engine(EngineConfig::default());
        eng.set_plan(plan);
        let got = eng.generate(&ps, steps).unwrap();
        assert_eq!(got, want, "tokens changed under plan {plan:?}");
    }
}

#[test]
fn omega_split_token_agreement_and_usage() {
    let steps = 5;
    let mut e0 = ref_engine(EngineConfig { omega: 0.0, ..EngineConfig::default() });
    let t0 = e0.generate(&prompts(), steps).unwrap();
    let mut e5 = ref_engine(EngineConfig { omega: 0.5, ..EngineConfig::default() });
    let t5 = e5.generate(&prompts(), steps).unwrap();
    assert_eq!(t0, t5, "omega=0.5 diverged");
    assert!(e5.metrics.cpu_attn_seqs > 0);
    assert!(e5.metrics.gpu_attn_seqs > 0);
    assert_eq!(e0.metrics.cpu_attn_seqs, 0);
}

#[test]
fn expert_batch_grows_with_accumulated_batch() {
    // Module-based batching's defining effect (paper Table 1): the average
    // per-expert batch grows with the accumulated batch B while a
    // model-based schedule (B = 1) keeps it tiny — with identical tokens
    // (checked in extreme_plans_are_throughput_only).
    let steps = 5;
    let mut big = ref_engine(EngineConfig::default());
    let _ = big.generate(&prompts(), steps).unwrap();
    let avg_big = big.metrics.avg_batch("expert_ffn");

    let mut small = ref_engine(EngineConfig { max_batch: 1, ..EngineConfig::default() });
    let _ = small.generate(&prompts(), steps).unwrap();
    let avg_small = small.metrics.avg_batch("expert_ffn");
    assert!(
        avg_big > 1.5 * avg_small,
        "accumulation must raise the expert batch: {avg_big} vs {avg_small}"
    );
}

#[test]
fn metrics_account_tokens_and_traffic() {
    let ps = prompts();
    let steps = 4;
    let mut eng = ref_engine(EngineConfig::default());
    let _ = eng.generate(&ps, steps).unwrap();
    let m = &eng.metrics;
    let prompt_tokens: usize = ps.iter().map(|p| p.len()).sum();
    assert_eq!(m.prefill_tokens as usize, prompt_tokens);
    assert_eq!(m.decode_tokens as usize, ps.len() * (steps - 1));
    assert!(m.htod_bytes > 0, "weight/activation traffic not metered");
    assert!(m.dtoh_bytes > 0, "KV writeback traffic not metered");
    assert!(m.modules.contains_key("expert_ffn"));
    assert!(m.avg_batch("expert_ffn") > 0.0);
    // The stage view covers the decode module graph.
    let stages: Vec<&str> = m.pipeline_stages().iter().map(|(n, _)| *n).collect();
    for kind in [ModuleKind::Embed, ModuleKind::AttnDecode, ModuleKind::ExpertFfn, ModuleKind::LmHead]
    {
        assert!(stages.contains(&kind.name()), "missing stage {}", kind.name());
    }
}

#[test]
fn kv_memory_accounted_and_released() {
    let mut eng = ref_engine(EngineConfig::default());
    let used_before = eng.host_pool.used();
    let _ = eng.generate(&prompts(), 3).unwrap();
    assert_eq!(
        eng.host_pool.used(),
        used_before,
        "KV host memory must be released after a batch completes"
    );
    assert!(eng.host_pool.peak() > used_before, "KV was never charged");
}

#[test]
fn profile_modules_covers_pipeline_stages_and_buckets() {
    let mut eng = ref_engine(EngineConfig::default());
    let prof = eng.profile_modules(3).unwrap();
    let experts: Vec<usize> = prof
        .iter()
        .filter(|(n, _, _)| n == "expert_ffn")
        .map(|&(_, b, _)| b)
        .collect();
    assert_eq!(experts, vec![8, 32, 128, 512]);
    for kind in [
        ModuleKind::Embed,
        ModuleKind::PreAttention,
        ModuleKind::AttnPrefill,
        ModuleKind::AttnDecode,
        ModuleKind::PostAttention,
        ModuleKind::Router,
        ModuleKind::ExpertFfn,
        ModuleKind::LmHead,
    ] {
        assert!(
            prof.iter().any(|(n, _, _)| n == kind.name()),
            "profile missing stage {}",
            kind.name()
        );
    }
    for (_, _, secs) in &prof {
        assert!(*secs >= 0.0);
    }
    // Profiling records through the same metrics sink the pipeline uses.
    assert!(!eng.metrics.pipeline_stages().is_empty());
}

#[test]
fn pipelined_executor_overlaps_and_matches_sequential_reference() {
    // The tentpole acceptance: under the module policy the wave executor
    // reports, from the virtual timeline, a makespan strictly below the
    // sum of per-stream busy time (overlap fraction > 0) — while greedy
    // tokens stay bit-identical to the sequential monolithic reference.
    let steps = 5;
    let want = RefMonolith::new().generate(&prompts(), steps);
    let mut eng = ref_engine(EngineConfig::default());
    let got = eng.generate(&prompts(), steps).unwrap();
    assert_eq!(got, want, "pipelined executor changed greedy tokens");
    eng.timeline.verify().unwrap();
    let st = eng.timeline.stats();
    assert!(st.ops > 0);
    for s in moe_gen::exec::Stream::ALL {
        assert!(
            st.busy(s) <= st.makespan_secs + 1e-9,
            "{} busy exceeds makespan",
            s.name()
        );
    }
    assert!(
        st.makespan_secs < st.busy_total(),
        "module policy must overlap streams: makespan {} vs busy {}",
        st.makespan_secs,
        st.busy_total()
    );
    assert!(st.overlap_fraction() > 0.0);
    assert_eq!(
        eng.metrics.timeline, st,
        "reported overlap must come from the timeline, not ad-hoc counters"
    );
}

#[test]
fn on_demand_policy_serializes_timeline_with_identical_tokens() {
    // The stall-per-launch baseline (prefetch off, cache off — what
    // `--policy deepspeed` maps to): the schedule degenerates to fully
    // serial, so the timeline reports exactly zero overlap; tokens still
    // match the reference bit-for-bit.
    let steps = 4;
    let want = RefMonolith::new().generate(&prompts(), steps);
    let mut eng = ref_engine(EngineConfig {
        prefetch: false,
        weight_cache_bytes: 0,
        ..EngineConfig::default()
    });
    let got = eng.generate(&prompts(), steps).unwrap();
    assert_eq!(got, want, "on-demand execution changed greedy tokens");
    eng.timeline.verify().unwrap();
    let st = eng.timeline.stats();
    assert!(st.ops > 0);
    assert!(
        (st.makespan_secs - st.busy_total()).abs() < 1e-6 * st.busy_total().max(1.0),
        "on-demand schedule must be fully serial: makespan {} vs busy {}",
        st.makespan_secs,
        st.busy_total()
    );
    assert_eq!(st.overlap_fraction(), 0.0);
}

#[test]
fn omega_split_rides_the_cpu_stream() {
    // With ω > 0 the CPU share lands on the CpuAttn stream and overlaps
    // the staged GPU attention — busy time on both compute streams.
    let mut eng = ref_engine(EngineConfig { omega: 0.5, ..EngineConfig::default() });
    let _ = eng.generate(&prompts(), 4).unwrap();
    let st = eng.timeline.stats();
    assert!(st.busy(moe_gen::exec::Stream::CpuAttn) > 0.0, "ω share missing from timeline");
    assert!(st.busy(moe_gen::exec::Stream::GpuCompute) > 0.0);
    assert!(st.busy(moe_gen::exec::Stream::DtoH) > 0.0, "KV appends must ride DtoH");
    assert!(st.overlap_fraction() > 0.0);
}

#[test]
fn phases_drain_all_outstanding_transfers() {
    // Every phase ends with a drain: nothing may remain in flight — not
    // in the pending list, not inside the weight cache.
    let mut eng = ref_engine(EngineConfig::default());
    let (mut state, _) = eng.prefill(&prompts()).unwrap();
    assert_eq!(eng.outstanding_transfers(), 0, "prefill left transfers in flight");
    let _ = eng.decode_step(&mut state).unwrap();
    assert_eq!(eng.outstanding_transfers(), 0, "decode left transfers in flight");
    let bytes = state.kv.read().unwrap().host_bytes();
    eng.host_pool.free(bytes);
}

#[test]
fn batch_composition_does_not_change_tokens() {
    let ps = prompts();
    let mut eng = ref_engine(EngineConfig::default());
    let solo = eng.generate(&ps[..1], 4).unwrap();
    let all = eng.generate(&ps, 4).unwrap();
    assert_eq!(solo[0], all[0]);
}
