//! Cross-module property tests: invariants that must hold over randomized
//! scenarios, wiring the coordinator's pieces together (the per-module
//! properties live next to each module in rust/src/*/mod.rs).

use moe_gen::batching::{gather_rows, micro_batches, scatter_add, GroupedBatch};
use moe_gen::dag::{Dag, Resource};
use moe_gen::hw;
use moe_gen::model;
use moe_gen::sched::{self, Knobs, Scenario, Strategy};
use moe_gen::util::prop::prop_check;
use moe_gen::util::rng::Rng;

fn random_scenario(rng: &mut Rng) -> Scenario {
    let m = match rng.below(5) {
        0 => model::mixtral_8x7b(),
        1 => model::mixtral_8x22b(),
        2 => model::deepseek_v2(),
        3 => model::deepseek_v2_lite(),
        _ => model::deepseek_r1(),
    };
    let h = match rng.below(3) {
        0 => hw::c1(),
        1 => hw::c2(),
        _ => hw::c3(),
    };
    let prompt = [128usize, 256, 512, 1024][rng.below(4)];
    let decode = [32usize, 256, 1024][rng.below(3)];
    Scenario::new(m, h, prompt, decode)
}

#[test]
fn prop_search_results_always_feasible() {
    // Whatever the search returns must satisfy Eqs. 2–3.
    prop_check(30, |rng| {
        let scn = random_scenario(rng);
        if sched::max_host_batch(&scn) == 0 {
            return;
        }
        for knobs in [Knobs::moe_gen(), Knobs::moe_gen_gpu_only()] {
            let r = sched::search_decode(&scn, &knobs);
            assert!(sched::host_feasible(&scn, r.strategy.b), "{:?}", r.strategy);
            assert!(sched::gpu_feasible(&scn, &r.strategy, true), "{:?}", r.strategy);
            assert!(r.throughput.is_finite() && r.throughput >= 0.0);
            assert!(r.strategy.omega >= 0.0 && r.strategy.omega <= 1.0);
        }
    });
}

#[test]
fn prop_decode_time_monotone_in_batch_work() {
    // A strictly larger accumulated batch cannot take *less* total work:
    // step time is non-decreasing in B (throughput may still rise).
    prop_check(20, |rng| {
        let scn = random_scenario(rng);
        let bmax = sched::max_host_batch(&scn);
        if bmax < 8 {
            return;
        }
        let b1 = rng.range(1, bmax / 2);
        let b2 = rng.range(b1, bmax);
        let mk = |b: usize| Strategy {
            b, b_a: 64, b_e: 8192, omega: 0.0,
            s_expert: 2 * scn.model.expert_bytes(), s_params: 0, reuse: 1.0,
            n_devices: 1, placement: moe_gen::batching::ExpertPlacement::RoundRobin,
            replication_bytes: 0,
        };
        let t1 = sched::decode_step_time(&scn, &mk(b1), &Knobs::moe_gen_gpu_only());
        let t2 = sched::decode_step_time(&scn, &mk(b2), &Knobs::moe_gen_gpu_only());
        assert!(
            t2 >= t1 * 0.999,
            "step time must not shrink with batch: B={b1}->{t1}, B={b2}->{t2}"
        );
    });
}

#[test]
fn prop_weight_reuse_never_hurts() {
    prop_check(20, |rng| {
        let scn = random_scenario(rng);
        if sched::max_host_batch(&scn) == 0 {
            return;
        }
        let s = Strategy {
            b: sched::max_host_batch(&scn).min(1024).max(1),
            b_a: 64, b_e: 8192, omega: 0.0,
            s_expert: 2 * scn.model.expert_bytes(), s_params: 0, reuse: 1.0,
            n_devices: 1, placement: moe_gen::batching::ExpertPlacement::RoundRobin,
            replication_bytes: 0,
        };
        let base = Knobs::moe_gen_gpu_only();
        let reused = Knobs { reuse: 4.0, ..base };
        let t_base = sched::decode_step_time(&scn, &s, &base);
        let t_reuse = sched::decode_step_time(&scn, &s, &reused);
        assert!(t_reuse <= t_base * 1.001, "reuse must not slow: {t_reuse} vs {t_base}");
    });
}

#[test]
fn prop_sim_traffic_monotone_in_dataset() {
    prop_check(20, |rng| {
        let scn = random_scenario(rng);
        if sched::max_host_batch(&scn) == 0 {
            return;
        }
        let n1 = rng.range(1, 10_000);
        let n2 = rng.range(n1, 20_000);
        for full in [true, false] {
            let t1 = moe_gen::sim::fetch_traffic_bytes(&scn, n1, full);
            let t2 = moe_gen::sim::fetch_traffic_bytes(&scn, n2, full);
            assert!(t2 >= t1, "traffic must grow with dataset ({full}): {t1} vs {t2}");
        }
    });
}

#[test]
fn prop_moe_combine_idempotent_under_micro_batching() {
    // Splitting an accumulated batch into arbitrary expert micro-batches
    // must not change the combined output (the b_e knob is throughput-
    // only). This is the algebraic heart of module-based batching.
    prop_check(60, |rng| {
        let n = rng.range(4, 120);
        let k = 2;
        let e = 8;
        let dim = 16;
        let x = rng.normal_vec(n * dim);
        let mut idx = Vec::new();
        let mut w = Vec::new();
        for _ in 0..n {
            let a = rng.below(e);
            let mut b = rng.below(e);
            if b == a {
                b = (b + 1) % e;
            }
            idx.extend([a as i32, b as i32]);
            let wa = rng.f64() as f32 + 0.1;
            w.extend([wa, 1.0 - wa]);
        }
        let run = |chunk: usize| {
            let g = GroupedBatch::build(&idx, &w, n, k, e);
            let mut acc = vec![0.0f32; n * dim];
            for ex in 0..e {
                let seg = g.segment(ex);
                for r in micro_batches(seg.len(), chunk) {
                    let abs = seg.start + r.start..seg.start + r.end;
                    let rows = &g.perm[abs.clone()];
                    let ws = &g.weights[abs];
                    let bucket = rows.len().next_power_of_two();
                    let gathered = gather_rows(&x, dim, rows, bucket);
                    scatter_add(&mut acc, dim, rows, ws, &gathered);
                }
            }
            acc
        };
        let a = run(usize::MAX);
        let b = run(rng.range(1, 16));
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5, "{u} vs {v}");
        }
    });
}

#[test]
fn prop_dag_edges_scale_linearly_with_layers() {
    // Builder sanity: nodes/edges per layer constant, no cross-layer leaks.
    prop_check(15, |rng| {
        let scn = random_scenario(rng);
        if sched::max_host_batch(&scn) == 0 {
            return;
        }
        let s = Strategy {
            b: 256, b_a: 64, b_e: 8192, omega: 0.3,
            s_expert: 2 * scn.model.expert_bytes(), s_params: 0, reuse: 1.0,
            n_devices: 1, placement: moe_gen::batching::ExpertPlacement::RoundRobin,
            replication_bytes: 0,
        };
        let g1 = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen(), 1);
        let g2 = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen(), 2);
        let g3 = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen(), 3);
        assert_eq!(g2.len() - g1.len(), g3.len() - g2.len());
        assert!(g3.topo_order().is_some());
        // Critical path grows with depth.
        assert!(g3.critical_path() > g2.critical_path());
        assert!(g2.critical_path() > g1.critical_path());
    });
}

#[test]
fn prop_dag_simulate_upper_bounds_dp_everywhere() {
    // Resource-aware greedy schedule can never beat the DP lower bound.
    prop_check(50, |rng| {
        let n = rng.range(2, 60);
        let mut g = Dag::new();
        for i in 0..n {
            let r = [Resource::GpuCompute, Resource::CpuCompute, Resource::HtoD, Resource::DtoH]
                [rng.below(4)];
            g.add(format!("n{i}"), rng.f64() * 5.0, r);
        }
        for v in 1..n {
            for _ in 0..rng.below(4) {
                g.edge(rng.below(v), v);
            }
        }
        assert!(g.critical_path() <= g.simulate() + 1e-9);
    });
}

#[test]
fn prop_feasibility_is_monotone_in_host_memory() {
    // Adding host memory can only help feasibility / max batch.
    prop_check(20, |rng| {
        let base = random_scenario(rng);
        let mut bigger = base.clone();
        bigger.hw.host_mem_bytes = base.hw.host_mem_bytes * 2;
        assert!(
            sched::max_host_batch(&bigger) >= sched::max_host_batch(&base),
            "more host memory must not shrink the feasible batch"
        );
    });
}
