//! Integration: expert-parallel scale-out to N virtual devices.
//!
//! Sharding the routed experts over a `Topology` of virtual devices — with
//! dispatch/combine all-to-all riding the shared interconnect stream — is a
//! *schedule* change, never a numeric one. The suite pins:
//!
//! * single-device equivalence: `n_devices = 1` is bit-identical to the
//!   pre-sharding path and placement is a no-op on its schedule;
//! * sharding invariance: greedy tokens are identical across
//!   `n_devices ∈ {1, 2, 4}` and all three placement policies, while the
//!   sharded schedules actually move all-to-all bytes;
//! * the dispatch→combine round trip is an identity permutation on token
//!   rows (property-tested over random router outputs);
//! * predicted overlap (`Dag::to_timeline()`) and the live
//!   `Metrics.timeline` agree on the schedule's character per policy.
//!
//! Everything runs hermetically on the reference backend.

use moe_gen::batching::{ExpertPlacement, GroupedBatch};
use moe_gen::config::EngineConfig;
use moe_gen::engine::Engine;
use moe_gen::exec::Stream;
use moe_gen::hw;
use moe_gen::model;
use moe_gen::runtime::{RefBackend, RtConfig};
use moe_gen::sched::{self, Knobs, Scenario, Strategy};
use moe_gen::util::prop::prop_check;
use moe_gen::workload;

fn engine(n_devices: usize, placement: ExpertPlacement) -> Engine {
    let backend = Box::new(RefBackend::new(RtConfig::tiny(), RefBackend::WEIGHT_SEED));
    Engine::with_backend(
        EngineConfig { n_devices, placement, ..EngineConfig::default() },
        backend,
    )
    .unwrap()
}

fn prompts() -> Vec<Vec<i32>> {
    workload::generate_prompts(6, 12, 40, 512, 3)
}

fn paper_scn(n_devices: usize) -> Scenario {
    Scenario::new(model::mixtral_8x7b(), hw::c2(), 512, 256).with_devices(n_devices)
}

#[test]
fn single_device_run_is_bit_identical_to_pre_sharding_path() {
    // n_devices = 1 takes the exact pre-sharding code path: no dispatch,
    // no combine, zero interconnect traffic, and a schedule with the same
    // op structure as the default engine's.
    let steps = 4;
    let mut base = engine(1, ExpertPlacement::RoundRobin);
    let want = base.generate(&prompts(), steps).unwrap();
    base.timeline.verify().unwrap();
    let base_st = base.timeline.stats();
    assert_eq!(base_st.devices, 1);
    assert_eq!(base_st.busy(Stream::Interconnect), 0.0, "nd=1 must not touch the interconnect");
    for placement in ExpertPlacement::ALL {
        let mut eng = engine(1, placement);
        let got = eng.generate(&prompts(), steps).unwrap();
        assert_eq!(got, want, "placement {placement:?} changed tokens at nd=1");
        let st = eng.timeline.stats();
        assert_eq!(st.ops, base_st.ops, "placement {placement:?} changed the nd=1 schedule");
        assert_eq!(st.busy(Stream::Interconnect), 0.0);
    }
}

#[test]
fn single_device_dag_makespan_is_placement_invariant() {
    // The modeled side of the same claim, where durations are
    // deterministic: a n_devices = 1 strategy replays to the identical
    // makespan whatever placement it carries — placement only exists in
    // the schedule once experts shard.
    let scn = paper_scn(1);
    let k = Knobs::moe_gen_gpu_only();
    let base = sched::search_decode(&scn, &k).strategy;
    let makespan = |placement| {
        let s = Strategy { placement, ..base };
        let tl = sched::build_decode_dag(&scn, &s, &k, 3).to_timeline();
        tl.verify().unwrap();
        (tl.makespan(), tl.busy(Stream::Interconnect))
    };
    let (m_rr, ici_rr) = makespan(ExpertPlacement::RoundRobin);
    assert_eq!(ici_rr, 0.0);
    for placement in ExpertPlacement::ALL {
        let (m, ici) = makespan(placement);
        assert_eq!(m, m_rr, "nd=1 makespan must be placement-invariant");
        assert_eq!(ici, 0.0);
    }
}

#[test]
fn tokens_invariant_across_device_counts_and_placements() {
    // Sharding invariance: the numeric expert loop is untouched by the
    // topology, so greedy tokens are bit-identical across every
    // (n_devices, placement) cell — while the nd > 1 schedules really
    // carry all-to-all traffic on the interconnect stream.
    let steps = 4;
    let want = engine(1, ExpertPlacement::RoundRobin)
        .generate(&prompts(), steps)
        .unwrap();
    for nd in [1usize, 2, 4] {
        for placement in ExpertPlacement::ALL {
            let mut eng = engine(nd, placement);
            let got = eng.generate(&prompts(), steps).unwrap();
            assert_eq!(got, want, "tokens diverged at nd={nd} placement={placement:?}");
            eng.timeline.verify().unwrap();
            let st = eng.timeline.stats();
            assert_eq!(st.devices, nd);
            let ici = st.busy(Stream::Interconnect);
            if nd == 1 {
                assert_eq!(ici, 0.0, "nd=1 must not touch the interconnect");
            } else {
                assert!(ici > 0.0, "nd={nd} {placement:?} moved no all-to-all bytes");
            }
        }
    }
}

#[test]
fn dispatch_combine_round_trip_is_identity_on_token_rows() {
    // The all-to-all pair's core contract: dispatching the grouped batch
    // to per-device token groups and combining the results back visits
    // every (token, rank) slot exactly once and restores the original
    // row order — an identity permutation, for any router output, any
    // device count and any placement.
    prop_check(60, |rng| {
        let n = rng.range(1, 33);
        let k = rng.range(1, 4);
        let num_experts = rng.range(k, 12);
        let nd = rng.range(1, 5);
        let placement = ExpertPlacement::ALL[rng.below(ExpertPlacement::ALL.len())];
        let mut idx = Vec::with_capacity(n * k);
        let mut wts = Vec::with_capacity(n * k);
        for _ in 0..n * k {
            idx.push(rng.below(num_experts) as i32);
            wts.push(rng.f64() as f32);
        }
        let g = GroupedBatch::build(&idx, &wts, n, k, num_experts);
        let counts: Vec<usize> = (0..num_experts).map(|e| g.count(e)).collect();
        let dev_of = placement.assign(num_experts, nd, Some(&counts));
        assert_eq!(dev_of.len(), num_experts);
        // Dispatch: per device, its experts' contiguous slot segments in
        // expert order — exactly the token groups the sharded expert
        // loop consumes.
        let mut dispatched: Vec<usize> = Vec::with_capacity(n * k);
        for d in 0..nd {
            for e in 0..num_experts {
                if dev_of[e] == d {
                    dispatched.extend(g.segment(e));
                }
            }
        }
        assert_eq!(dispatched.len(), n * k, "dispatch must cover every slot once");
        // Combine: scatter each device's results back by source slot.
        let mut back = vec![usize::MAX; n * k];
        for (i, &slot) in dispatched.iter().enumerate() {
            assert_eq!(back[slot], usize::MAX, "slot {slot} dispatched twice");
            back[slot] = i;
        }
        let restored: Vec<usize> = back.iter().map(|&i| dispatched[i]).collect();
        let identity: Vec<usize> = (0..n * k).collect();
        assert_eq!(restored, identity, "dispatch→combine must be the identity");
        // And the round trip preserves each slot's token row.
        for (slot, &row) in g.perm.iter().enumerate() {
            assert_eq!(g.perm[dispatched[back[slot]]], row);
        }
    });
}

#[test]
fn predicted_and_live_overlap_agree_on_schedule_character() {
    // The shared-model contract: `Dag::to_timeline()` (the search's
    // scorer) and the live `Metrics.timeline` describe the same schedule
    // semantics. Absolute times differ (the live run measures the tiny
    // reference backend's wall clock), so the pin is the schedule's
    // character: the module policy overlaps in both views, the on-demand
    // baseline serializes in both.
    let scn = paper_scn(1);
    let module = Knobs::moe_gen_gpu_only();
    let s = sched::search_decode(&scn, &module).strategy;
    let on_demand = Knobs { prefetch: false, ..module };
    let pred_module = sched::predicted_overlap(&scn, &s, &module, true);
    let pred_on_demand = sched::predicted_overlap(&scn, &s, &on_demand, true);
    assert!(pred_module > 0.0 && pred_module < 1.0);
    assert!(pred_on_demand < pred_module, "prediction must rank on-demand below module");

    let mut live_module = engine(1, ExpertPlacement::RoundRobin);
    let _ = live_module.generate(&prompts(), 4).unwrap();
    let o_live = live_module.metrics.timeline.overlap_fraction();
    assert!(o_live > 0.0 && o_live < 1.0, "live module policy must overlap: {o_live}");

    let backend = Box::new(RefBackend::new(RtConfig::tiny(), RefBackend::WEIGHT_SEED));
    let mut live_od = Engine::with_backend(
        EngineConfig { prefetch: false, weight_cache_bytes: 0, ..EngineConfig::default() },
        backend,
    )
    .unwrap();
    let _ = live_od.generate(&prompts(), 4).unwrap();
    assert_eq!(
        live_od.metrics.timeline.overlap_fraction(),
        0.0,
        "live on-demand schedule must serialize exactly"
    );
}

#[test]
fn searched_multidev_strategy_overlaps_interconnect_with_compute() {
    // Acceptance: a searched n_devices = 2 strategy replays with the
    // all-to-all priced on the interconnect stream and hidden under FFN
    // compute — overlap strictly better than the serialized schedule of
    // the same DAG.
    let scn = paper_scn(2);
    let k = Knobs::moe_gen_gpu_only();
    let res = sched::search_decode(&scn, &k);
    assert_eq!(res.strategy.n_devices, 2);
    let g = sched::build_decode_dag(&scn, &res.strategy, &k, 3);
    let tl = g.to_timeline();
    tl.verify().unwrap();
    assert!(tl.busy(Stream::Interconnect) > 0.0);
    let ser = g.to_timeline_mode(true);
    assert_eq!(ser.overlap_fraction(), 0.0);
    assert!(
        tl.overlap_fraction() > 0.0 && tl.makespan() < ser.makespan(),
        "sharded schedule must overlap: {} vs serialized {}",
        tl.makespan(),
        ser.makespan()
    );
}
