//! Integration: the live rust engine end-to-end against the python golden
//! trace, plus cross-policy agreement (greedy decode must be invariant to
//! batching policy) and the ω-split numerical-consistency contract.

use xla::FromRawBytes;

use moe_gen::config::EngineConfig;
use moe_gen::engine::Engine;

fn art_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine(omega: f64) -> Engine {
    let cfg = EngineConfig {
        artifacts_dir: art_dir(),
        omega,
        ..EngineConfig::default()
    };
    Engine::new(cfg).expect("artifacts missing — run `make artifacts`")
}

/// Golden trace from artifacts/golden.npz: (prompts, steps-tokens matrix).
fn golden_trace() -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
    let g: std::collections::HashMap<String, xla::Literal> =
        xla::Literal::read_npz(art_dir().join("golden.npz"), &())
            .expect("golden.npz missing")
            .into_iter()
            .collect();
    let lens: Vec<i32> = g["trace.lens"].to_vec().unwrap();
    let pmat: Vec<i32> = g["trace.prompts"].to_vec().unwrap();
    let maxlen = pmat.len() / lens.len();
    let prompts: Vec<Vec<i32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &l)| pmat[i * maxlen..i * maxlen + l as usize].to_vec())
        .collect();
    let tmat: Vec<i32> = g["trace.tokens"].to_vec().unwrap();
    let steps = tmat.len() / lens.len();
    let tokens: Vec<Vec<i32>> = (0..lens.len())
        .map(|i| tmat[i * steps..(i + 1) * steps].to_vec())
        .collect();
    (prompts, tokens)
}

#[test]
fn engine_reproduces_python_golden_trace() {
    // The core e2e correctness claim: the rust coordinator, running the
    // same XLA module programs with the same padding and combine rules,
    // generates the exact token stream the python reference engine did.
    let (prompts, want) = golden_trace();
    let steps = want[0].len();
    let mut eng = engine(0.0);
    let got = eng.generate(&prompts, steps).unwrap();
    assert_eq!(got, want, "token streams diverged from golden trace");
}

#[test]
fn batch_composition_does_not_change_tokens() {
    // A sequence decoded alongside different companions must produce the
    // same greedy tokens (padding isolation across the whole stack).
    let (prompts, _) = golden_trace();
    let mut eng = engine(0.0);
    let solo = eng.generate(&prompts[..1], 6).unwrap();
    let all = eng.generate(&prompts, 6).unwrap();
    assert_eq!(solo[0], all[0]);
}

#[test]
fn omega_split_token_agreement() {
    // The paper's numerical-consistency contract (App. B): running part of
    // the batch's attention on the CPU kernel (bf16-consistent) must not
    // change greedy tokens on a well-separated vocab.
    let (prompts, _) = golden_trace();
    let steps = 8;
    let mut g0 = engine(0.0);
    let t0 = g0.generate(&prompts, steps).unwrap();
    let mut g5 = engine(0.5);
    let t5 = g5.generate(&prompts, steps).unwrap();
    let mut g10 = engine(1.0);
    let t10 = g10.generate(&prompts, steps).unwrap();
    assert_eq!(t0, t5, "omega=0.5 diverged");
    assert_eq!(t0, t10, "omega=1.0 diverged");
    // And the CPU path was actually used.
    assert!(g5.metrics.cpu_attn_seqs > 0);
    assert!(g5.metrics.gpu_attn_seqs > 0);
    assert!(g10.metrics.gpu_attn_seqs == 0);
}

#[test]
fn metrics_account_tokens_and_traffic() {
    let (prompts, _) = golden_trace();
    let mut eng = engine(0.0);
    let steps = 4;
    let _ = eng.generate(&prompts, steps).unwrap();
    let m = &eng.metrics;
    let prompt_tokens: usize = prompts.iter().map(|p| p.len()).sum();
    assert_eq!(m.prefill_tokens as usize, prompt_tokens);
    assert_eq!(m.decode_tokens as usize, prompts.len() * (steps - 1));
    assert!(m.htod_bytes > 0, "weight/activation traffic not metered");
    assert!(m.dtoh_bytes > 0, "KV writeback traffic not metered");
    // Module-based batching signature: experts saw accumulated tokens.
    assert!(m.modules.contains_key("expert_ffn"));
    assert!(m.avg_batch("expert_ffn") > 0.0);
}

#[test]
fn expert_batch_grows_with_accumulated_batch() {
    // Module-based batching's defining effect (paper Table 1): the average
    // per-expert batch grows with the accumulated batch B while
    // model-based batching (small chunks) keeps it tiny.
    let (prompts, _) = golden_trace();
    // Module-based over all 4 sequences at once:
    let mut big = engine(0.0);
    let _ = big.generate(&prompts, 6).unwrap();
    let avg_big = big.metrics.avg_batch("expert_ffn");
    // "Model-based" here: max_batch=1 forces per-sequence forward passes.
    let mut small = Engine::new(EngineConfig {
        artifacts_dir: art_dir(),
        max_batch: 1,
        ..EngineConfig::default()
    })
    .unwrap();
    let _ = small.generate(&prompts, 6).unwrap();
    let avg_small = small.metrics.avg_batch("expert_ffn");
    assert!(
        avg_big > 1.5 * avg_small,
        "accumulation must raise expert batch: {avg_big} vs {avg_small}"
    );
    // ... while producing identical tokens (already checked above).
}

#[test]
fn kv_memory_accounted_and_released() {
    let (prompts, _) = golden_trace();
    let mut eng = engine(0.0);
    let used_before = eng.host_pool.used();
    let _ = eng.generate(&prompts, 3).unwrap();
    assert_eq!(
        eng.host_pool.used(),
        used_before,
        "KV host memory must be released after a batch completes"
    );
    assert!(eng.host_pool.peak() > used_before, "KV was never charged");
}

#[test]
fn rejects_oversized_and_empty_prompts() {
    let mut eng = engine(0.0);
    let too_long = vec![vec![1i32; 65]];
    assert!(eng.generate(&too_long, 2).is_err());
    let empty = vec![vec![]];
    assert!(eng.generate(&empty, 2).is_err());
}

#[test]
fn profile_modules_covers_buckets() {
    let mut eng = engine(0.0);
    let prof = eng.profile_modules(3).unwrap();
    let experts: Vec<usize> = prof
        .iter()
        .filter(|(n, _, _)| n == "expert_ffn")
        .map(|&(_, b, _)| b)
        .collect();
    assert_eq!(experts, vec![8, 32, 128, 512]);
    for (_, _, secs) in &prof {
        assert!(*secs > 0.0);
    }
    // The reps knob is validated, and a single-rep profile still covers
    // the same stage × bucket grid.
    assert!(eng.profile_modules(0).is_err(), "zero reps must be rejected");
    let prof1 = eng.profile_modules(1).unwrap();
    assert_eq!(prof1.len(), prof.len(), "reps must not change profile coverage");
}
