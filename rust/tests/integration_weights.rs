//! Integration: the weight-residency subsystem on the live pipeline.
//!
//! Residency is a transfer/placement policy only: greedy tokens must be
//! bit-identical with the cache enabled (any budget) or disabled (the
//! stall-per-launch path), with prefetch on or off. On the default
//! configuration the cache must actually work: nonzero hit-rate, issued
//! predictive prefetches consumed in flight, budget never exceeded.
//!
//! Everything runs hermetically on the reference backend. `run_offline`
//! is exercised on purpose: it is a deprecated thin wrapper over the
//! session layer and must stay behaviour-identical until removal.
#![allow(deprecated)]

use moe_gen::config::{EngineConfig, Policy};
use moe_gen::engine::Engine;
use moe_gen::runtime::{RefBackend, RtConfig};
use moe_gen::server;
use moe_gen::weights::WeightSizes;
use moe_gen::workload;

fn ref_engine(cfg: EngineConfig) -> Engine {
    let backend = Box::new(RefBackend::new(RtConfig::tiny(), RefBackend::WEIGHT_SEED));
    Engine::with_backend(cfg, backend).unwrap()
}

fn prompts() -> Vec<Vec<i32>> {
    workload::generate_prompts(6, 12, 40, 512, 3)
}

#[test]
fn tokens_bit_identical_with_cache_on_off_and_tiny_budget() {
    let steps = 5;
    let mut on = ref_engine(EngineConfig::default());
    let t_on = on.generate(&prompts(), steps).unwrap();
    assert!(on.metrics.weight_hits > 0, "default budget must produce cache hits");

    // Cache off + on-demand fetches: the stall-per-launch baseline path.
    let mut off = ref_engine(EngineConfig {
        weight_cache_bytes: 0,
        prefetch: false,
        ..EngineConfig::default()
    });
    let t_off = off.generate(&prompts(), steps).unwrap();
    assert_eq!(t_on, t_off, "residency must not change greedy tokens");
    assert_eq!(off.metrics.weight_hits, 0, "disabled cache cannot hit");
    assert!(off.metrics.htod_stalled_bytes > 0, "on-demand fetches stall");

    // A budget of two experts forces constant eviction — tokens still match.
    let sizes = WeightSizes::from_cfg(&RtConfig::tiny());
    let mut tiny = ref_engine(EngineConfig {
        weight_cache_bytes: 2 * sizes.expert,
        ..EngineConfig::default()
    });
    let t_tiny = tiny.generate(&prompts(), steps).unwrap();
    assert_eq!(t_on, t_tiny, "eviction pressure must not change greedy tokens");
    assert!(tiny.metrics.weight_evictions > 0, "tiny budget must evict");
    assert!(
        tiny.metrics.weight_hit_rate() < on.metrics.weight_hit_rate(),
        "eviction pressure must cost hit-rate"
    );
}

#[test]
fn predictive_prefetch_issues_and_is_consumed_in_flight() {
    let mut eng = ref_engine(EngineConfig::default());
    let _ = eng.generate(&prompts(), 4).unwrap();
    let m = &eng.metrics;
    assert!(m.prefetch_issued > 0, "dense streams / hot experts must be issued");
    assert!(m.prefetch_hits > 0, "the next-layer dense stream must be consumed in flight");
    assert!(m.htod_overlapped_bytes > 0, "prefetched bytes overlap compute");
    assert_eq!(m.htod_stalled_bytes, 0, "prefetch mode never stalls a launch");
}

#[test]
fn cache_budget_is_a_hard_invariant_live() {
    let sizes = WeightSizes::from_cfg(&RtConfig::tiny());
    let budget = sizes.dense_layer + 2 * sizes.expert;
    let mut eng = ref_engine(EngineConfig {
        weight_cache_bytes: budget,
        ..EngineConfig::default()
    });
    let _ = eng.generate(&prompts(), 4).unwrap();
    assert!(eng.weights.cache.peak_bytes() <= budget, "budget exceeded during the run");
    assert!(eng.weights.cache.used() <= budget);
}

#[test]
fn run_offline_reports_residency_per_policy() {
    // MoE-Gen (module policy): cache on, nonzero hit-rate in the report —
    // the acceptance criterion behind `moe-gen run --policy module`.
    let rep = server::run_offline(EngineConfig::default(), &prompts(), 4).unwrap();
    assert_eq!(rep.policy, Policy::ModuleBased);
    assert!(rep.weight_hit_rate > 0.0, "module policy must report cache hits");
    assert!(rep.htod_overlap_fraction > 0.0);
    assert!(rep.summary().contains("cache-hit="));

    // DeepSpeed-style model-based policy: weights stream per launch.
    let cfg = EngineConfig { policy: Policy::ModelBased, ..EngineConfig::default() };
    let rep_ds = server::run_offline(cfg, &prompts(), 4).unwrap();
    assert_eq!(rep_ds.weight_hit_rate, 0.0, "on-demand baseline has no cache");
    // Staged KV windows still overlap, but weight fetches stall — the
    // overlap fraction must sit strictly below the prefetching policy's.
    assert!(
        rep_ds.htod_overlap_fraction < rep.htod_overlap_fraction,
        "on-demand ({}) must overlap less than prefetch ({})",
        rep_ds.htod_overlap_fraction,
        rep.htod_overlap_fraction
    );
    assert_eq!(rep.tokens, rep_ds.tokens, "policies must agree on greedy tokens");
}

#[test]
fn reuse_factor_is_live_and_changes_eviction_dynamics() {
    // The FlexGen/MoE-Lightning reuse factor holds each fetch sticky for
    // `reuse` launches. Under a tight budget that must change which
    // entries get evicted or bypassed relative to plain LRU — while
    // greedy tokens stay identical. This guards the reuse plumbing
    // (EngineConfig::weight_reuse → Plan::reuse → sticky rounds): if it
    // is severed, both runs degenerate to the same cache trace.
    let sizes = WeightSizes::from_cfg(&RtConfig::tiny());
    let mk = |reuse: f64| EngineConfig {
        weight_cache_bytes: 2 * sizes.expert,
        weight_reuse: reuse,
        prefetch: false, // isolate reuse: no speculative entries
        ..EngineConfig::default()
    };
    let mut lru = ref_engine(mk(1.0));
    let t_lru = lru.generate(&prompts(), 4).unwrap();
    let mut held = ref_engine(mk(4.0));
    let t_held = held.generate(&prompts(), 4).unwrap();
    assert_eq!(t_lru, t_held, "reuse must not change greedy tokens");
    let (a, b) = (lru.weights.cache.stats(), held.weights.cache.stats());
    assert_ne!(
        (a.hits, a.misses, a.evictions, a.bypasses),
        (b.hits, b.misses, b.evictions, b.bypasses),
        "reuse 4.0 must alter the cache trace vs plain LRU"
    );
    // Sticky entries block eviction, so the held run bypasses more.
    assert!(b.bypasses > a.bypasses, "sticky fetches must force bypasses: {b:?} vs {a:?}");

    // And the policy mapping keeps FlexGen's reuse sourced from Knobs.
    let rep_fg = server::run_offline(
        EngineConfig { policy: Policy::FlexGen, ..EngineConfig::default() },
        &prompts(),
        3,
    )
    .unwrap();
    let rep_mb = server::run_offline(EngineConfig::default(), &prompts(), 3).unwrap();
    assert_eq!(rep_fg.tokens, rep_mb.tokens, "policies must agree on greedy tokens");
}

#[test]
fn searched_strategy_budget_goes_live() {
    use moe_gen::sched::Strategy;
    let mut eng = ref_engine(EngineConfig::default());
    let sizes = WeightSizes::from_cfg(&RtConfig::tiny());
    let dec = Strategy {
        b: 16,
        b_a: 8,
        b_e: 128,
        omega: 0.0,
        s_expert: 3 * sizes.expert,
        s_params: sizes.total(),
        reuse: 1.0,
        n_devices: 1,
        placement: moe_gen::batching::ExpertPlacement::RoundRobin,
        replication_bytes: 0,
    };
    eng.set_strategy(&dec, None);
    assert_eq!(eng.weights.cache.budget(), sizes.total());
    assert_eq!(eng.weights.sched.buffer_bytes, Some(3 * sizes.expert));
    // Big enough to hold everything: a short run misses each key once.
    let toks = eng.generate(&prompts(), 3).unwrap();
    assert_eq!(toks.len(), 6);
    assert!(eng.metrics.weight_hit_rate() > 0.5);
}

#[test]
fn replication_lifts_expert_hit_rate_without_changing_tokens() {
    // Cross-request expert replication (DESIGN.md §14) is a residency
    // policy only: greedy tokens are bit-identical with it off, fully
    // budgeted, or squeezed to one slot. Under a two-expert cache the
    // demand path thrashes (every launch sweeps more experts than fit),
    // so pinning the cross-request-hot experts as sticky replicas must
    // strictly lift the expert hit-rate on the skewed router trace the
    // reference model produces.
    let steps = 10;
    let sizes = WeightSizes::from_cfg(&RtConfig::tiny());
    let mk = |rep: usize| EngineConfig {
        weight_cache_bytes: 2 * sizes.expert,
        prefetch: false, // isolate replication from predictive prefetch
        replication_bytes: Some(rep),
        ..EngineConfig::default()
    };

    let mut off = ref_engine(mk(0));
    let t_off = off.generate(&prompts(), steps).unwrap();
    assert_eq!(off.weights.cache.replicated_bytes(), 0, "rep=0 forces replication off");
    assert_eq!(off.metrics.expert_replicated_hits, 0);

    let mut on = ref_engine(mk(2 * sizes.expert));
    let t_on = on.generate(&prompts(), steps).unwrap();
    assert_eq!(t_off, t_on, "replication must not change greedy tokens");
    assert!(
        on.weights.cache.replicated_bytes() > 0,
        "a confident skewed table must install replicas"
    );
    assert!(
        on.metrics.expert_replicated_hits > 0,
        "hot experts must serve launches from their sticky replicas"
    );
    assert!(
        on.metrics.expert_hit_rate() > off.metrics.expert_hit_rate(),
        "replication must lift expert hit-rate: on={} off={}",
        on.metrics.expert_hit_rate(),
        off.metrics.expert_hit_rate()
    );

    // One-slot budget: still token-identical, replicas capped at one expert.
    let mut tiny = ref_engine(mk(sizes.expert));
    let t_tiny = tiny.generate(&prompts(), steps).unwrap();
    assert_eq!(t_off, t_tiny, "a tiny replication budget must not change greedy tokens");
    assert!(tiny.weights.cache.replicated_bytes() <= sizes.expert, "budget caps the replica set");
}
