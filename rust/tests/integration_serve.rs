//! Integration: the online serving subsystem against the offline driver.
//!
//! The serving contract (DESIGN.md §7):
//!
//! * **serving ≡ offline** — under a trace where everything arrives at
//!   t = 0 and nothing hits EOS, `serve` emits bit-identical greedy
//!   tokens to `run_offline` for the same prompts (wave membership is
//!   throughput-only, like every other batching knob);
//! * **apples-to-apples policies** — module-based and continuous serving
//!   run the identical arrival trace and emit identical tokens;
//! * **backfill saturation** — with backfill enabled, the `expert_ffn`
//!   average batch under module policy stays within 25% of the offline
//!   value while sequences drain;
//! * **slot lifecycle** — no slot leaks, and a recycled slot's successor
//!   reproduces a fresh run's tokens exactly.
//!
//! Everything runs hermetically on the reference backend. The legacy
//! one-shot entrypoints (`run_offline`, `serve::serve`) are exercised on
//! purpose: they are deprecated thin wrappers over the session layer and
//! must stay behaviour-identical until removal
//! (tests/integration_spec.rs pins wrapper ≡ session).
#![allow(deprecated)]

use moe_gen::config::{EngineConfig, Policy};
use moe_gen::serve::{self, Request, ServeConfig};
use moe_gen::server;
use moe_gen::workload::{self, ArrivalMode, ArrivalSpec};

fn prompts(n: usize) -> Vec<Vec<i32>> {
    workload::generate_prompts(n, 12, 40, 512, 3)
}

/// Requests over `prompts` with a fixed decode budget and given arrivals.
fn fixed_requests(prompts: &[Vec<i32>], max_new: usize, arrivals: &[u64]) -> Vec<Request> {
    prompts
        .iter()
        .zip(arrivals)
        .enumerate()
        .map(|(id, (p, &arrival))| Request {
            id,
            prompt: p.clone(),
            max_new,
            arrival,
            ..Request::default()
        })
        .collect()
}

fn eng_cfg(policy: Policy) -> EngineConfig {
    EngineConfig { policy, ..EngineConfig::default() }
}

#[test]
fn serve_at_t0_without_eos_matches_run_offline() {
    let ps = prompts(10);
    let steps = 5;
    let offline = server::run_offline(eng_cfg(Policy::ModuleBased), &ps, steps).unwrap();

    let cfg = ServeConfig {
        eng: eng_cfg(Policy::ModuleBased),
        arrival: ArrivalSpec::at_time_zero(),
        eos: None,
        ..ServeConfig::default()
    };
    let reqs = fixed_requests(&ps, steps, &vec![0; ps.len()]);
    let rep = serve::serve(&cfg, reqs).unwrap();

    assert_eq!(rep.tokens, offline.tokens, "serve diverged from the offline driver");
    assert_eq!(rep.requests, 10);
    assert_eq!(rep.finished_max, 10, "EOS disabled: everything runs to budget");
    assert_eq!(rep.finished_eos, 0);
    assert_eq!(rep.leaked_slots, 0, "slots must all be recycled");
    assert_eq!(rep.decode_tokens, 10 * (steps as u64 - 1));
}

#[test]
fn module_and_continuous_serve_the_same_trace_with_identical_tokens() {
    let ps = prompts(8);
    let arrival = ArrivalSpec {
        mode: ArrivalMode::OpenLoop { mean_gap: 1.0 },
        seed: 9,
        ..ArrivalSpec::default()
    };
    let arrivals = arrival.arrival_ticks(ps.len());
    let mut reports = Vec::new();
    for policy in [Policy::ModuleBased, Policy::Continuous] {
        let cfg = ServeConfig {
            eng: eng_cfg(policy),
            arrival,
            ..ServeConfig::default()
        };
        let reqs = fixed_requests(&ps, 5, &arrivals);
        reports.push(serve::serve(&cfg, reqs).unwrap());
    }
    let (m, c) = (&reports[0], &reports[1]);
    assert_eq!(m.tokens, c.tokens, "policy changed greedy tokens");
    for rep in [m, c] {
        assert_eq!(rep.finished_max, 8);
        assert_eq!(rep.leaked_slots, 0);
        assert!(rep.decode_waves > 0);
        assert!(rep.total_tp > 0.0);
        // Latency percentiles are populated and ordered.
        assert!(rep.ttft_p99 >= rep.ttft_p50 && rep.ttft_p50 >= 0.0);
        assert!(rep.tpot_p99 >= rep.tpot_p50 && rep.tpot_p50 >= 0.0);
    }
    // Continuous batching admits into a pool of baseline_micro_batch
    // slots; module policy waves at B.
    assert!(c.peak_slots <= 8);
}

#[test]
fn backfill_keeps_expert_batch_near_offline_while_draining() {
    // 24 requests against B = 16: the first wave fills B, the rest must
    // be backfilled as earlier sequences drain at varying budgets.
    let ps = prompts(24);
    let budgets = workload::decode_lengths(24, 6, 2, 8, 11);
    let mean_steps = 6;
    let base = EngineConfig { max_batch: 16, ..eng_cfg(Policy::ModuleBased) };

    let offline = server::run_offline(base.clone(), &ps, mean_steps).unwrap();

    let mk_reqs = || {
        ps.iter()
            .zip(&budgets)
            .enumerate()
            .map(|(id, (p, &b))| Request {
                id,
                prompt: p.clone(),
                max_new: b,
                arrival: 0,
                ..Request::default()
            })
            .collect::<Vec<_>>()
    };
    let cfg = ServeConfig {
        eng: base.clone(),
        arrival: ArrivalSpec::at_time_zero(),
        backfill: true,
        ..ServeConfig::default()
    };
    let rep = serve::serve(&cfg, mk_reqs()).unwrap();
    assert!(rep.backfilled > 0, "the trailing 8 requests must backfill a live wave");
    assert_eq!(rep.leaked_slots, 0);
    assert_eq!(rep.finished_eos + rep.finished_max, 24);
    // The acceptance bar: module batches stay saturated while draining.
    assert!(
        rep.expert_avg_batch >= 0.75 * offline.expert_avg_batch,
        "backfill failed to keep expert batches large: serve {:.2} vs offline {:.2}",
        rep.expert_avg_batch,
        offline.expert_avg_batch
    );

    // Backfill off = wave-at-a-time: nothing joins a live wave.
    let cfg_off = ServeConfig { backfill: false, ..cfg };
    let rep_off = serve::serve(&cfg_off, mk_reqs()).unwrap();
    assert_eq!(rep_off.backfilled, 0);
    assert_eq!(rep_off.tokens, rep.tokens, "backfill is throughput-only");
}

#[test]
fn eos_terminates_streams_early_as_prefixes() {
    let ps = prompts(6);
    let steps = 8;
    let offline = server::run_offline(eng_cfg(Policy::ModuleBased), &ps, steps).unwrap();
    // Choose a token that provably occurs mid-stream: sequence 0's 4th.
    let eos = offline.tokens[0][3];

    let cfg = ServeConfig {
        eng: eng_cfg(Policy::ModuleBased),
        arrival: ArrivalSpec::at_time_zero(),
        eos: Some(eos),
        ..ServeConfig::default()
    };
    let rep = serve::serve(&cfg, fixed_requests(&ps, steps, &[0; 6])).unwrap();
    assert!(rep.finished_eos >= 1, "sequence 0 must finish on EOS");
    assert_eq!(rep.leaked_slots, 0, "early exits must still recycle slots");
    for (full, cut) in offline.tokens.iter().zip(&rep.tokens) {
        match full.iter().position(|&t| t == eos) {
            Some(p) => assert_eq!(cut, &full[..=p], "EOS stream must be a prefix (incl. EOS)"),
            None => assert_eq!(cut, full, "EOS-free stream must match the offline run"),
        }
    }
    // Sequence 0 stops at its first occurrence of the chosen token.
    let p0 = offline.tokens[0].iter().position(|&t| t == eos).unwrap();
    assert_eq!(rep.tokens[0].len(), p0 + 1);
    assert!(rep.tokens[0].len() <= 4);
}

#[test]
fn recycled_slot_reproduces_fresh_tokens() {
    // A single-slot pool forces every request through the same recycled
    // slot, one at a time; tokens must equal a fresh offline run.
    let ps = prompts(5);
    let steps = 4;
    let offline = server::run_offline(eng_cfg(Policy::ModuleBased), &ps, steps).unwrap();
    let cfg = ServeConfig {
        eng: eng_cfg(Policy::ModuleBased),
        arrival: ArrivalSpec::at_time_zero(),
        kv_slots: Some(1),
        ..ServeConfig::default()
    };
    let rep = serve::serve(&cfg, fixed_requests(&ps, steps, &[0; 5])).unwrap();
    assert_eq!(rep.peak_slots, 1, "one slot serves everything sequentially");
    assert_eq!(rep.tokens, offline.tokens, "recycled slot corrupted a successor");
    assert_eq!(rep.leaked_slots, 0);
}

#[test]
fn closed_loop_concurrency_bounds_the_in_flight_set() {
    let ps = prompts(9);
    let cfg = ServeConfig {
        eng: eng_cfg(Policy::ModuleBased),
        arrival: ArrivalSpec {
            mode: ArrivalMode::ClosedLoop { concurrency: 3 },
            ..ArrivalSpec::default()
        },
        ..ServeConfig::default()
    };
    let rep = serve::serve(&cfg, fixed_requests(&ps, 4, &[0; 9])).unwrap();
    assert!(rep.peak_slots <= 3, "closed loop must cap in-flight at the concurrency");
    assert_eq!(rep.finished_max, 9);
    assert_eq!(rep.leaked_slots, 0);
}

#[test]
fn serve_under_byte_budget_respects_eq2_sizing() {
    let ps = prompts(6);
    // Budget for exactly two sequences' KV: admission must never hold
    // more than two slots.
    let c = moe_gen::runtime::RtConfig::tiny();
    let slot_bytes = moe_gen::kv::KvCache::slot_bytes_for(
        c.num_layers,
        c.num_kv_heads,
        c.head_dim,
        c.max_context,
    );
    let cfg = ServeConfig {
        eng: eng_cfg(Policy::ModuleBased),
        arrival: ArrivalSpec::at_time_zero(),
        kv_budget_bytes: Some(2 * slot_bytes + slot_bytes / 3),
        ..ServeConfig::default()
    };
    let rep = serve::serve(&cfg, fixed_requests(&ps, 3, &[0; 6])).unwrap();
    assert!(rep.peak_slots <= 2, "byte budget admits at most two sequences");
    assert_eq!(rep.finished_max, 6);
    assert_eq!(rep.leaked_slots, 0);
}
