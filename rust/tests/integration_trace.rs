//! Integration: whole-run tracing (Chrome-trace export + roofline).
//!
//! Drives real jobs through the [`Session`] library path with a
//! `trace_out` temp file — the same code `moe-gen run --trace-out`
//! executes — and pins the exporter's contract:
//!
//! * the file parses as a Chrome trace-event JSON document;
//! * duration-event timestamps are monotonic within every track (the
//!   per-lane FIFO the virtual timeline guarantees must survive export);
//! * every flow finish (`ph: "f"`) pairs with an emitted start
//!   (`ph: "s"`) of the same id, on a different track;
//! * live runs emit at least one counter sample per executed wave;
//! * a serialized baseline's trace (`--policy model`, the
//!   DeepSpeed-style on-demand regime) shows zero overlapping ops
//!   anywhere — its makespan IS the sum of its op durations;
//! * the analytic roofline bounds the strategy search: predicted
//!   throughput lands in `(0, 1]` of the ceiling for every paper
//!   model × testbed the search solves.
//!
//! Everything runs hermetically on the reference backend.

use std::path::PathBuf;

use moe_gen::config::Policy;
use moe_gen::hw;
use moe_gen::model;
use moe_gen::sched::{self, Knobs, Scenario};
use moe_gen::session::Session;
use moe_gen::spec::{JobKind, JobSpec, WorkloadSpec};
use moe_gen::trace::roofline;
use moe_gen::util::json::Json;

fn tmp_trace(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("moe_gen_integration_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let _ = std::fs::remove_file(&path);
    path
}

fn spec_with_trace(path: &std::path::Path, policy: Policy) -> JobSpec {
    let mut spec = JobSpec {
        workload: WorkloadSpec { num_requests: 4, mean_prompt: 8, max_prompt: 16, steps: 4 },
        bench_log: None,
        trace_out: Some(path.to_path_buf()),
        ..JobSpec::default()
    };
    spec.eng.policy = policy;
    spec
}

/// Run one offline job and parse the trace it exported.
fn run_and_load(name: &str, policy: Policy) -> (Json, usize) {
    let path = tmp_trace(name);
    let mut s = Session::open(spec_with_trace(&path, policy)).unwrap();
    s.run().unwrap();
    let waves = s.engine().metrics.waves.len();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);
    (doc, waves)
}

/// The duration events (`ph: "X"`) as `(tid, ts, dur)` rows.
fn slices(doc: &Json) -> Vec<(f64, f64, f64)> {
    doc.req("traceEvents")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.req("ph").as_str() == Some("X"))
        .map(|e| {
            (
                e.req("tid").as_f64().unwrap(),
                e.req("ts").as_f64().unwrap(),
                e.req("dur").as_f64().unwrap(),
            )
        })
        .collect()
}

#[test]
fn module_run_trace_parses_with_monotonic_tracks() {
    let (doc, _) = run_and_load("module.json", Policy::ModuleBased);
    let evs = doc.req("traceEvents").as_arr().unwrap();
    assert!(!evs.is_empty());
    // Every event carries the minimal Chrome fields.
    for e in evs {
        assert!(e.req("ph").as_str().is_some());
        assert!(e.req("pid").as_f64().is_some());
    }
    // Timestamps must be non-decreasing within each track, in emission
    // order — the per-lane FIFO the timeline schedules by.
    let rows = slices(&doc);
    assert!(rows.len() > 10, "a real run has a real op history: {}", rows.len());
    let mut last: std::collections::BTreeMap<i64, f64> = Default::default();
    for (tid, ts, _) in rows {
        let k = tid as i64;
        if let Some(prev) = last.get(&k) {
            assert!(ts >= *prev - 1e-6, "track {k} went backwards: {ts} after {prev}");
        }
        last.insert(k, ts);
    }
    // The run metadata block travels with the trace.
    let other = doc.req("otherData");
    assert_eq!(other.req("job").as_str(), Some("run"));
    assert!(other.req("truncated").as_bool().is_some());
    assert!(other.req("makespan_secs").as_f64().unwrap() > 0.0);
}

#[test]
fn flow_finishes_reference_emitted_starts() {
    let (doc, _) = run_and_load("flows.json", Policy::ModuleBased);
    let evs = doc.req("traceEvents").as_arr().unwrap();
    let mut starts: std::collections::BTreeMap<i64, f64> = Default::default();
    for e in evs.iter().filter(|e| e.req("ph").as_str() == Some("s")) {
        starts.insert(e.req("id").as_f64().unwrap() as i64, e.req("tid").as_f64().unwrap());
    }
    let finishes: Vec<&Json> =
        evs.iter().filter(|e| e.req("ph").as_str() == Some("f")).collect();
    assert!(!finishes.is_empty(), "the module policy's dep edges must draw flow arrows");
    assert_eq!(starts.len(), finishes.len(), "every flow is one s/f pair");
    for f in finishes {
        let id = f.req("id").as_f64().unwrap() as i64;
        let src_tid = starts.get(&id).expect("finish without a start");
        assert_ne!(
            *src_tid,
            f.req("tid").as_f64().unwrap(),
            "flow {id} must cross lanes (same-lane order is implicit)"
        );
        assert_eq!(f.req("bp").as_str(), Some("e"));
    }
}

#[test]
fn live_run_samples_a_counter_per_wave() {
    let (doc, waves) = run_and_load("counters.json", Policy::ModuleBased);
    assert!(waves >= 4, "4 decode steps must record at least 4 waves, got {waves}");
    let evs = doc.req("traceEvents").as_arr().unwrap();
    let batch_samples = evs
        .iter()
        .filter(|e| e.req("ph").as_str() == Some("C"))
        .filter(|e| e.req("name").as_str() == Some("expert_avg_batch"))
        .count();
    assert_eq!(batch_samples, waves, "one expert_avg_batch sample per executed wave");
    // All five counter series ride along.
    for series in
        ["expert_avg_batch", "weight_cache_hit_rate", "arena_hit_rate", "kv_slots", "queue_depth"]
    {
        assert!(
            evs.iter().any(|e| e.req("ph").as_str() == Some("C")
                && e.req("name").as_str() == Some(series)),
            "missing counter series {series}"
        );
    }
}

#[test]
fn serialized_baseline_trace_has_zero_overlap() {
    // The model-based (DeepSpeed-style) baseline serializes every op:
    // its exported schedule must show no two ops overlapping in time,
    // on any pair of tracks.
    let (doc, _) = run_and_load("serialized.json", Policy::ModelBased);
    assert_eq!(doc.req("otherData").req("serialized").as_bool(), Some(true));
    let mut rows = slices(&doc);
    assert!(!rows.is_empty());
    rows.sort_by(|a, b| a.1.total_cmp(&b.1));
    let mut cursor = 0.0f64;
    for (_, ts, dur) in rows {
        assert!(
            ts >= cursor - 1e-3,
            "serialized trace overlaps: op at {ts}µs starts before {cursor}µs"
        );
        cursor = cursor.max(ts + dur);
    }
}

#[test]
fn serve_trace_exports_queue_depth_counters() {
    let path = tmp_trace("serve.json");
    let mut spec = spec_with_trace(&path, Policy::ModuleBased);
    spec.kind = JobKind::Serve;
    spec.serve.mean_decode = 2;
    spec.serve.max_decode = 4;
    let mut s = Session::open(spec).unwrap();
    s.serve().unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(doc.req("otherData").req("job").as_str(), Some("serve"));
    let evs = doc.req("traceEvents").as_arr().unwrap();
    assert!(
        evs.iter().any(|e| e.req("ph").as_str() == Some("C")
            && e.req("name").as_str() == Some("queue_depth")),
        "serving traces must carry the admission queue-depth counter track"
    );
}

#[test]
fn roofline_bounds_the_search_on_every_paper_config() {
    // The analytic roofline drops every lower-order term (PCIe, embed,
    // LM head, attention arithmetic), so it upper-bounds any schedule
    // the search can produce: predicted/ceiling must land in (0, 1].
    let models =
        ["mixtral-8x7b", "mixtral-8x22b", "deepseek-v2", "deepseek-v2-lite", "deepseek-r1"];
    let testbeds = ["c1", "c2", "c3"];
    let mut solved = 0;
    for mn in models {
        let Some(m) = model::by_name(mn) else { panic!("unknown paper model {mn}") };
        for tn in testbeds {
            let h = hw::by_name(tn).unwrap();
            let scn = Scenario::new(m.clone(), h.clone(), 512, 256);
            let res = sched::search_decode(&scn, &Knobs::moe_gen());
            if res.throughput <= 0.0 {
                continue; // infeasible pairing (model too big for testbed)
            }
            solved += 1;
            let rl = roofline::decode_roofline(&scn.model, &scn.hw, res.strategy.b);
            assert!(rl.tokens_per_sec > 0.0, "{mn}/{tn}: degenerate ceiling");
            let f = roofline::fraction(res.throughput, rl.tokens_per_sec);
            assert!(
                f > 0.0 && f <= 1.0,
                "{mn}/{tn}: roofline_fraction {f} outside (0,1] \
                 (search {:.1} tok/s vs ceiling {:.1} tok/s)",
                res.throughput,
                rl.tokens_per_sec,
            );
        }
    }
    assert!(solved >= 6, "search must solve most paper configs, solved {solved}");
}
