//! Integration: the typed `JobSpec`/`Session` public API (DESIGN.md §8).
//!
//! The contracts this file pins:
//!
//! * **round-trip** — `dump → load` is the identity on a fully
//!   non-default spec (the `--config`/`--dump-config` CLI contract);
//! * **validation** — bad states (ω ∉ [0,1], `b_a > B`, …) fail at
//!   build time with field-naming errors, never deep in the pipeline;
//! * **the closed loop** — `profile → search → apply → run` executes the
//!   searched strategy: the engine's active `Plan` equals the searched
//!   strategy's projection, and the tokens are bit-identical to an
//!   explicit `set_strategy` run of the same strategy (batch-composition
//!   invariance, the pipeline's core contract);
//! * **wrapper equivalence** — the deprecated one-shot free functions
//!   (`server::run_offline`, `serve::serve`) remain behaviour-identical
//!   to the session path until removal.
//!
//! Everything runs hermetically on the reference backend.

use moe_gen::config::Policy;
use moe_gen::engine::Engine;
use moe_gen::exec::Plan;
use moe_gen::server;
use moe_gen::session::{Session, StrategyBasis};
use moe_gen::spec::{JobKind, JobSpec, SearchBasis, StrategySource, WorkloadSpec};
use moe_gen::workload;

fn small_spec() -> JobSpec {
    JobSpec {
        workload: WorkloadSpec { num_requests: 6, mean_prompt: 10, max_prompt: 24, steps: 4 },
        bench_log: None,
        ..JobSpec::default()
    }
}

// -- round-trip ---------------------------------------------------------------

#[test]
fn dump_load_identity_for_cli_built_specs() {
    // The shapes the CLI actually produces: defaults, a serve job, and a
    // searched-strategy run.
    let mut serve = small_spec();
    serve.kind = JobKind::Serve;
    serve.eng.policy = Policy::Continuous;
    serve.serve.eos = Some(3);
    let mut searched = small_spec();
    searched.strategy = StrategySource::Searched;
    searched.search_basis = SearchBasis::Measured;
    for spec in [JobSpec::default(), small_spec(), serve, searched] {
        let reloaded: JobSpec = spec.dump().parse().unwrap();
        assert_eq!(reloaded, spec);
    }
}

#[test]
fn validate_catches_bad_states_before_any_engine_exists() {
    let cases: Vec<(&str, Box<dyn Fn(&mut JobSpec)>)> = vec![
        ("omega", Box::new(|s| s.eng.omega = 7.0)),
        ("b_a > B", Box::new(|s| s.eng.attn_micro = s.eng.max_batch * 2)),
        ("zero workload", Box::new(|s| s.workload.num_requests = 0)),
        ("zero steps", Box::new(|s| s.workload.steps = 0)),
        ("unknown model", Box::new(|s| s.scenario.model = "granite-13b".into())),
        ("unknown testbed", Box::new(|s| s.scenario.testbed = "c9".into())),
        ("serve policy", Box::new(|s| {
            s.kind = JobKind::Serve;
            s.eng.policy = Policy::FlexGen;
        })),
        ("decode budgets", Box::new(|s| {
            s.serve.mean_decode = 10;
            s.serve.max_decode = 2;
        })),
    ];
    for (name, mutate) in cases {
        let mut spec = small_spec();
        mutate(&mut spec);
        let err = spec.validate();
        assert!(err.is_err(), "{name}: must be rejected");
        // And the session constructor enforces it too.
        assert!(Session::open(spec).is_err(), "{name}: Session::open must reject");
    }
}

// -- the closed loop ----------------------------------------------------------

#[test]
fn profile_search_apply_run_executes_the_searched_strategy() {
    let mut spec = small_spec();
    spec.strategy = StrategySource::Searched;
    spec.search_basis = SearchBasis::Measured;
    let mut session = Session::open(spec).unwrap();

    // profile → search: the cost model is the measured module profile.
    assert!(!session.profile().unwrap().is_empty());
    let outcome = session.search().unwrap();
    assert_eq!(outcome.basis, StrategyBasis::MeasuredProfile);
    assert!(outcome.decode.validate().is_ok(), "searched strategy: {:?}", outcome.decode);

    // apply: the engine's live plan IS the searched strategy's projection.
    let plan = session.apply().unwrap();
    let expected = Plan::from_strategy(
        &outcome.decode,
        outcome.prefill.as_ref(),
        session.engine().model_cfg(),
        session.spec().eng.max_batch,
    );
    assert_eq!(plan, expected, "applied plan must equal the searched strategy");
    assert_eq!(session.plan(), expected, "the session's engine runs on it");

    // run: tokens bit-identical to an explicit set_strategy run of the
    // same strategy on a fresh engine (strategy flows, tokens invariant).
    let prompts = workload::generate_prompts(6, 10, 24, 512, 9);
    let report = session.run_prompts(&prompts, 4).unwrap();

    let mut eng = Engine::new(session.spec().eng.clone()).unwrap();
    eng.warmup().unwrap();
    eng.set_strategy(&outcome.decode, outcome.prefill.as_ref());
    assert_eq!(eng.plan(), expected);
    let explicit = eng.generate(&prompts, 4).unwrap();
    assert_eq!(report.tokens, explicit, "searched-run tokens must match explicit set_strategy");
}

#[test]
fn explicit_strategy_source_applies_verbatim() {
    let decode = moe_gen::sched::Strategy {
        b: 16, b_a: 4, b_e: 32, omega: 0.0, s_expert: 1 << 20, s_params: 1 << 22, reuse: 2.0,
        n_devices: 1, placement: moe_gen::batching::ExpertPlacement::RoundRobin,
        replication_bytes: 0,
    };
    let mut spec = small_spec();
    spec.strategy = StrategySource::Explicit { decode, prefill: None };
    let mut session = Session::open(spec).unwrap();
    let plan = session.apply().unwrap();
    assert_eq!(plan.accum_batch, 16);
    assert_eq!(plan.attn_micro, 4);
    assert_eq!(plan.expert_micro, 32);
    // Residency fields went live on the engine.
    assert_eq!(session.engine().weights.cache.budget(), 1 << 22);
    assert_eq!(session.engine().weights.sched.buffer_bytes, Some(1 << 20));
}

#[test]
fn analytic_fallback_produces_an_executable_strategy() {
    let mut spec = small_spec();
    spec.strategy = StrategySource::Searched;
    spec.search_basis = SearchBasis::Analytic;
    let mut session = Session::open(spec).unwrap();
    let outcome = session.search().unwrap();
    assert_eq!(outcome.basis, StrategyBasis::AnalyticModel);
    // A paper-scale strategy applies to the tiny engine: B caps at the
    // engine budget, micro-batches clamp at launch, and the run works.
    session.apply().unwrap();
    assert!(session.plan().accum_batch <= session.spec().eng.max_batch);
    let report = session.run().unwrap();
    assert_eq!(report.tokens.len(), 6);
}

// -- strategy invariance across sources --------------------------------------

#[test]
fn tokens_invariant_across_strategy_sources() {
    // Defaults vs searched vs explicit: batching strategy must never
    // change greedy tokens (so `--strategy search` is always safe).
    let prompts = workload::generate_prompts(5, 8, 20, 512, 21);
    let mut tokens: Vec<Vec<Vec<i32>>> = Vec::new();
    for strategy in [
        StrategySource::EngineDefaults,
        StrategySource::Searched,
        StrategySource::Explicit {
            decode: moe_gen::sched::Strategy {
                b: 8, b_a: 2, b_e: 16, omega: 0.5, s_expert: 0, s_params: 0, reuse: 1.0,
                n_devices: 1, placement: moe_gen::batching::ExpertPlacement::RoundRobin,
                replication_bytes: 0,
            },
            prefill: None,
        },
    ] {
        let mut spec = small_spec();
        spec.strategy = strategy;
        let mut session = Session::open(spec).unwrap();
        tokens.push(session.run_prompts(&prompts, 4).unwrap().tokens);
    }
    assert_eq!(tokens[0], tokens[1], "searched strategy changed tokens");
    assert_eq!(tokens[0], tokens[2], "explicit strategy changed tokens");
}

// -- wrapper equivalence ------------------------------------------------------

#[test]
#[allow(deprecated)]
fn deprecated_run_offline_matches_session_run() {
    let prompts = workload::generate_prompts(6, 10, 24, 512, 5);
    let spec = small_spec();
    let legacy = server::run_offline(spec.eng.clone(), &prompts, 4).unwrap();
    let mut session = Session::open(spec).unwrap();
    let rep = session.run_prompts(&prompts, 4).unwrap();
    assert_eq!(legacy.tokens, rep.tokens);
    assert_eq!(legacy.prefill_tokens, rep.prefill_tokens);
    assert_eq!(legacy.decode_tokens, rep.decode_tokens);
}

#[test]
#[allow(deprecated)]
fn deprecated_serve_matches_session_serve() {
    let mut spec = small_spec();
    spec.kind = JobKind::Serve;
    spec.serve.mean_decode = 2;
    spec.serve.max_decode = 4;
    let scfg = spec.serve_config();
    let requests = moe_gen::serve::synth_requests(&scfg, 512);
    let legacy = moe_gen::serve::serve(&scfg, requests.clone()).unwrap();
    let mut session = Session::open(spec).unwrap();
    let rep = session.serve_requests(requests).unwrap();
    assert_eq!(legacy.tokens, rep.tokens);
    assert_eq!(legacy.finished_eos, rep.finished_eos);
    assert_eq!(legacy.finished_max, rep.finished_max);
}
