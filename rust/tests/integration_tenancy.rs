//! Integration: the multi-tenant serving layer (DESIGN.md §13).
//!
//! The tenancy contract:
//!
//! * **scheduling is throughput-only** — SLO priority, decode-wave
//!   preemption, chunked prefill and shared-prefix dedup are all
//!   latency/memory knobs: for the same request set they emit
//!   bit-identical greedy token streams (wave membership never changes
//!   the math, and a donor's copied prefix rows equal recomputed ones);
//! * **SLO classes pay off** — on a mixed 50/50 burst, latency-class
//!   p99 TTFT under the SLO scheduler beats plain FIFO by at least 2×
//!   while total work is unchanged;
//! * **aging prevents starvation** — a batch-class request facing a
//!   continuous latency stream is promoted after `AGING_TICKS` and
//!   finishes in bounded time;
//! * **no slot is ever leaked or double-owned** — across random
//!   admit/preempt/finish interleavings the KV pool accounting stays
//!   exact, donors refcount correctly, and teardown returns every byte.
//!
//! Everything runs hermetically on the reference backend; the legacy
//! `serve::serve` wrapper is exercised on purpose (deprecated thin
//! wrapper over the session layer, behaviour-pinned until removal).
#![allow(deprecated)]

use moe_gen::config::{EngineConfig, Policy};
use moe_gen::engine::Engine;
use moe_gen::serve::{
    self, AdmissionController, Class, ClassStats, Request, ServeConfig, ServeReport, WaveScheduler,
};
use moe_gen::util::prop::prop_check;
use moe_gen::workload::{self, ArrivalSpec};

/// A narrow engine (wave width 4) so a handful of requests exercises
/// queueing, preemption and seat contention.
fn narrow_eng() -> EngineConfig {
    EngineConfig {
        policy: Policy::ModuleBased,
        max_batch: 4,
        attn_micro: 2,
        ..EngineConfig::default()
    }
}

fn class_stats(rep: &ServeReport, class: Class) -> &ClassStats {
    rep.classes
        .iter()
        .find(|c| c.class == class)
        .unwrap_or_else(|| panic!("report has no stats for {class:?}"))
}

#[test]
fn preemption_is_token_invariant_and_parks_batch_work() {
    // 12 long batch-class decodes arrive at t = 0 and fill the 4-wide
    // wave; 6 short latency-class requests trickle in afterwards. With
    // more KV slots (8) than wave seats (4), the preemptor must park
    // decoding batch work (keeping its slot) to seat them immediately.
    let ps = workload::generate_prompts(18, 6, 10, 512, 21);
    let mk_reqs = || {
        ps.iter()
            .enumerate()
            .map(|(id, p)| {
                let latency = id >= 12;
                Request {
                    id,
                    prompt: p.clone(),
                    max_new: if latency { 3 } else { 12 },
                    arrival: if latency { 2 + (id as u64 - 12) } else { 0 },
                    class: if latency { Class::LatencySensitive } else { Class::ThroughputBatch },
                    ..Request::default()
                }
            })
            .collect::<Vec<_>>()
    };
    let cfg = ServeConfig {
        eng: narrow_eng(),
        arrival: ArrivalSpec::at_time_zero(),
        kv_slots: Some(8),
        slo: true,
        preempt: true,
        ..ServeConfig::default()
    };
    let rep_on = serve::serve(&cfg, mk_reqs()).unwrap();
    let cfg_off = ServeConfig { preempt: false, ..cfg };
    let rep_off = serve::serve(&cfg_off, mk_reqs()).unwrap();

    assert!(rep_on.preemptions > 0, "slots outnumber seats: batch work must park");
    assert!(rep_on.parked_peak >= 1);
    assert_eq!(rep_off.preemptions, 0, "preemption disabled must never park");
    assert_eq!(
        rep_on.tokens, rep_off.tokens,
        "preemption changed greedy tokens (must be throughput-only)"
    );
    for rep in [&rep_on, &rep_off] {
        assert_eq!(rep.finished_eos + rep.finished_max, 18);
        assert_eq!(rep.leaked_slots, 0, "parked slots must all come back");
    }
    // Parking exists to serve latency-class work sooner.
    let on = class_stats(&rep_on, Class::LatencySensitive);
    let off = class_stats(&rep_off, Class::LatencySensitive);
    assert!(
        on.ttft_p99_ticks <= off.ttft_p99_ticks,
        "preemption made latency TTFT worse: {} vs {}",
        on.ttft_p99_ticks,
        off.ttft_p99_ticks
    );
}

#[test]
fn slo_scheduling_beats_fifo_on_latency_class_ttft() {
    // A 50/50 mixed burst at t = 0: short latency-class requests
    // interleaved (by id) with long batch-class decodes, through a
    // 4-seat wave. FIFO admits in id order, so latency work queues
    // behind whole batch waves; the SLO scheduler seats every
    // latency-class request first.
    let ps = workload::generate_prompts(32, 6, 10, 512, 17);
    let mk_reqs = || {
        ps.iter()
            .enumerate()
            .map(|(id, p)| {
                let latency = id % 2 == 1;
                Request {
                    id,
                    prompt: p.clone(),
                    max_new: if latency { 2 } else { 10 },
                    arrival: 0,
                    class: if latency { Class::LatencySensitive } else { Class::ThroughputBatch },
                    ..Request::default()
                }
            })
            .collect::<Vec<_>>()
    };
    let base = ServeConfig {
        eng: narrow_eng(),
        arrival: ArrivalSpec::at_time_zero(),
        kv_slots: Some(4),
        ..ServeConfig::default()
    };
    let fifo = serve::serve(&base, mk_reqs()).unwrap();
    let slo = serve::serve(&ServeConfig { slo: true, ..base }, mk_reqs()).unwrap();

    // Same math, same work: scheduling only moves latency around.
    assert_eq!(slo.tokens, fifo.tokens, "SLO scheduling changed greedy tokens");
    assert_eq!(slo.decode_tokens, fifo.decode_tokens);
    for rep in [&fifo, &slo] {
        assert_eq!(rep.finished_eos + rep.finished_max, 32);
        assert_eq!(rep.leaked_slots, 0);
    }
    // The acceptance bar: latency-class p99 TTFT at least 2x better.
    let f = class_stats(&fifo, Class::LatencySensitive);
    let s = class_stats(&slo, Class::LatencySensitive);
    assert_eq!(f.requests, 16);
    assert_eq!(s.requests, 16);
    assert!(
        2.0 * s.ttft_p99_ticks <= f.ttft_p99_ticks,
        "SLO p99 TTFT {} ticks is not 2x better than FIFO {} ticks",
        s.ttft_p99_ticks,
        f.ttft_p99_ticks
    );
    assert!(s.ttft_p50_ticks < f.ttft_p50_ticks, "median latency-class TTFT must improve too");
}

#[test]
fn prefix_dedup_is_token_invariant_and_saves_kv_bytes() {
    // Ten requests sharing a 4-token prefix: with dedup on, the first
    // admission installs a donor and every later one copies the donor's
    // rows instead of re-prefilling them. Tokens must not move.
    let prefix = [11, 22, 33, 44];
    let mk_reqs = || {
        (0..10)
            .map(|id| {
                let mut prompt = prefix.to_vec();
                prompt.extend([100 + id as i32, 7]);
                Request {
                    id,
                    prompt,
                    max_new: 4,
                    arrival: 0,
                    prefix_len: prefix.len(),
                    ..Request::default()
                }
            })
            .collect::<Vec<_>>()
    };
    let cfg = ServeConfig {
        eng: narrow_eng(),
        arrival: ArrivalSpec::at_time_zero(),
        kv_slots: Some(6),
        prefix_dedup: true,
        ..ServeConfig::default()
    };
    let rep_on = serve::serve(&cfg, mk_reqs()).unwrap();
    let cfg_off = ServeConfig { prefix_dedup: false, ..cfg };
    let rep_off = serve::serve(&cfg_off, mk_reqs()).unwrap();

    assert!(rep_on.dedup_hits > 0, "sharers must admit through the donor");
    assert!(rep_on.dedup_bytes > 0, "donor copies must account saved KV bytes");
    assert_eq!(rep_off.dedup_hits, 0);
    assert_eq!(rep_off.dedup_bytes, 0);
    assert_eq!(
        rep_on.tokens, rep_off.tokens,
        "prefix dedup changed greedy tokens (copied rows must equal recomputed rows)"
    );
    for rep in [&rep_on, &rep_off] {
        assert_eq!(rep.finished_eos + rep.finished_max, 10);
        assert_eq!(rep.leaked_slots, 0, "donor slots must drain, not leak");
    }
}

#[test]
fn chunked_prefill_is_token_invariant() {
    // Long prompts pushed through a 3-token prefill budget per tick:
    // admissions span several ticks as partials, but the resumable
    // prefill is bit-identical to the whole-prompt one.
    let ps = workload::generate_prompts(8, 12, 20, 512, 5);
    let mk_reqs = || {
        ps.iter()
            .enumerate()
            .map(|(id, p)| Request {
                id,
                prompt: p.clone(),
                max_new: 4,
                arrival: 0,
                ..Request::default()
            })
            .collect::<Vec<_>>()
    };
    let base = ServeConfig {
        eng: narrow_eng(),
        arrival: ArrivalSpec::at_time_zero(),
        kv_slots: Some(4),
        ..ServeConfig::default()
    };
    let whole = serve::serve(&base, mk_reqs()).unwrap();
    let chunked =
        serve::serve(&ServeConfig { prefill_chunk_tokens: Some(3), ..base }, mk_reqs()).unwrap();

    assert_eq!(chunked.tokens, whole.tokens, "chunked prefill changed greedy tokens");
    for rep in [&whole, &chunked] {
        assert_eq!(rep.finished_eos + rep.finished_max, 8);
        assert_eq!(rep.leaked_slots, 0);
    }
}

#[test]
fn aging_prevents_batch_class_starvation() {
    // One batch-class request vs a continuous latency stream through a
    // single seat. Pure priority would starve it until the stream ends
    // (~24 ticks); aging promotes it to rank 0 after AGING_TICKS (8),
    // and its earlier arrival then wins the tie, bounding its TTFT.
    let ps = workload::generate_prompts(13, 5, 8, 512, 31);
    let reqs: Vec<Request> = ps
        .iter()
        .enumerate()
        .map(|(id, p)| {
            if id == 0 {
                Request { id, prompt: p.clone(), max_new: 3, arrival: 0, ..Request::default() }
            } else {
                Request {
                    id,
                    prompt: p.clone(),
                    max_new: 2,
                    arrival: id as u64 - 1,
                    class: Class::LatencySensitive,
                    ..Request::default()
                }
            }
        })
        .collect();
    let cfg = ServeConfig {
        eng: EngineConfig { max_batch: 1, attn_micro: 1, ..narrow_eng() },
        arrival: ArrivalSpec::at_time_zero(),
        kv_slots: Some(1),
        slo: true,
        preempt: false,
        ..ServeConfig::default()
    };
    let rep = serve::serve(&cfg, reqs).unwrap();
    assert_eq!(rep.finished_eos + rep.finished_max, 13);
    assert_eq!(rep.leaked_slots, 0);
    let batch = class_stats(&rep, Class::ThroughputBatch);
    assert_eq!(batch.requests, 1);
    assert!(
        batch.ttft_p99_ticks <= 16.0,
        "aged batch request waited {} ticks: starved past the aging bound",
        batch.ttft_p99_ticks
    );
    assert_eq!(class_stats(&rep, Class::LatencySensitive).requests, 12);
}

#[test]
fn prop_random_admit_preempt_finish_interleavings_never_leak() {
    // 100 random interleavings of admit (plain / via-donor / installing
    // a donor), decode-wave preemption (park), resume and finish over a
    // small shared pool. Throughout: the pool accounting is exact, no
    // KV slot is ever owned twice, donor refcounts equal the live
    // sharers, and teardown returns the pool to zero bytes.
    fn finish(
        i: usize,
        sched: &mut WaveScheduler,
        adm: &mut AdmissionController,
        live: &mut Vec<(usize, usize, bool)>,
        prefix: &[i32],
    ) {
        let (id, slot) = sched.retire(i);
        let pos = live
            .iter()
            .position(|&(lid, _, _)| lid == id)
            .expect("retired a request that was never admitted");
        let (_, admitted_slot, has_ref) = live.swap_remove(pos);
        assert_eq!(admitted_slot, slot, "scheduler returned a different slot than admitted");
        if has_ref {
            adm.release_prefix_ref(prefix);
        }
        adm.recycle(slot);
    }

    prop_check(100, |rng| {
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        let total = rng.range(3, 8);
        let mut adm = AdmissionController::with_slots(&mut eng, total).unwrap();
        let mut sched = WaveScheduler::new(adm.kv(), total, 1, 1, true);
        let prefix: Vec<i32> = vec![3, 1, 4];
        let mut next_id = 0usize;
        // Live requests: (id, slot, holds a donor reference).
        let mut live: Vec<(usize, usize, bool)> = Vec::new();
        let mut parks = 0u64;

        for _ in 0..rng.range(20, 80) {
            match rng.below(4) {
                0 | 1 => {
                    // Admit: claim a slot (evicting an idle donor under
                    // pressure), optionally through or installing the donor.
                    if let Some(slot) = adm.alloc_slot() {
                        let donor_up = adm.donors().iter().any(|e| e.key == prefix);
                        let mut has_ref = false;
                        if donor_up && rng.f64() < 0.5 {
                            assert_eq!(adm.admit_via_donor(&prefix, slot), Some(prefix.len()));
                            has_ref = true;
                        } else if rng.f64() < 0.3 {
                            adm.kv().write().unwrap().set_len(slot, prefix.len());
                            has_ref = adm.install_donor(&prefix, slot);
                        }
                        adm.note_admitted(1);
                        sched.push(next_id, slot, 1, 7);
                        live.push((next_id, slot, has_ref));
                        next_id += 1;
                    }
                }
                2 => {
                    // Preempt: park a random in-flight request (keeps slot).
                    if sched.in_flight() > 0 {
                        let i = rng.below(sched.in_flight());
                        sched.park(i);
                        parks += 1;
                    }
                }
                _ => {
                    // Finish: retire a random in-flight request; resume a
                    // parked one first when the decode set ran dry.
                    if sched.in_flight() == 0 && !sched.parked.is_empty() {
                        sched.resume_one();
                    }
                    if sched.in_flight() > 0 {
                        let i = rng.below(sched.in_flight());
                        finish(i, &mut sched, &mut adm, &mut live, &prefix);
                    }
                }
            }

            // Invariants after every operation.
            assert_eq!(sched.in_flight() + sched.parked.len(), live.len());
            assert_eq!(adm.slots_in_use(), live.len() + adm.donors().len());
            assert!(adm.slots_in_use() <= adm.total_slots(), "pool over-committed");
            let refs: usize = adm.donors().iter().map(|e| e.refs).sum();
            assert_eq!(refs, live.iter().filter(|&&(_, _, r)| r).count());
            let mut owned: Vec<usize> = sched.state.slots.clone();
            owned.extend(sched.parked.iter().map(|p| p.slot));
            owned.extend(adm.donors().iter().map(|e| e.slot));
            let n_owned = owned.len();
            owned.sort_unstable();
            owned.dedup();
            assert_eq!(owned.len(), n_owned, "a KV slot is owned twice (double free ahead)");
        }

        // Drain: resume everything parked, finish everything in flight.
        while sched.resume_one().is_some() {}
        while sched.in_flight() > 0 {
            finish(0, &mut sched, &mut adm, &mut live, &prefix);
        }
        assert!(live.is_empty());
        assert_eq!(sched.preemptions, parks);
        adm.drain_donors();
        assert_eq!(adm.slots_in_use(), 0, "slots leaked after drain");
        adm.shutdown(&mut eng);
        assert_eq!(eng.host_pool.used(), 0, "host pool bytes leaked");
    });
}
