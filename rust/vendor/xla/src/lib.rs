//! Offline **API stub** of the PJRT `xla` bindings.
//!
//! The optional `pjrt` feature of `moe_gen` compiles against this crate so
//! the live-hardware code path stays buildable in environments with no
//! crates registry and no XLA toolchain. Every entry point that would
//! touch a real PJRT client fails at runtime with [`Error::Stub`];
//! deployments with hardware replace this directory with the real
//! bindings (identical surface, cf. `/opt/xla-example/load_hlo`).

use std::path::Path;
use std::rc::Rc;

/// Stub error: always signals that the real bindings are absent.
#[derive(Debug)]
pub enum Error {
    Stub(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Stub(what) => write!(
                f,
                "xla stub: {what} requires the real PJRT bindings (see rust/vendor/README.md)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &'static str) -> Result<T> {
    Err(Error::Stub(what))
}

/// Element types the engine traffics in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Native host types that map onto [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }

    pub fn ty(&self) -> Result<ElementType> {
        stub("Literal::ty")
    }

    pub fn size_bytes(&self) -> usize {
        0
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

/// Loading literals from serialized containers (.npz).
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npz(path: impl AsRef<Path>, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();
    fn read_npz(_path: impl AsRef<Path>, _ctx: &()) -> Result<Vec<(String, Literal)>> {
        stub("Literal::read_npz")
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// A computation ready for PJRT compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle (`Rc`-based, not `Send`).
pub struct PjRtBuffer {
    _not_send: Rc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT device client.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}
