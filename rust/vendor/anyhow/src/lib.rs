//! Minimal offline subset of the `anyhow` crate.
//!
//! Provides the `Error` type, the `Result` alias, the `anyhow!` / `bail!`
//! macros and the `Context` extension trait — the API surface this
//! repository actually uses. Errors carry a formatted message chain only
//! (no backtraces, no downcasting).
//!
//! Mirrors real `anyhow` in one load-bearing way: `Error` deliberately
//! does NOT implement `std::error::Error`, which is what makes the
//! blanket `From<E: std::error::Error>` conversion (the `?` operator on
//! `io::Error` etc.) coherent.

use std::fmt;

/// A formatted, chainable error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context line (most recent context first, like anyhow).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>`: `Result<T, anyhow::Error>` by default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(|| ..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

/// Marker so the `Option` impl does not overlap the `Result` impl.
pub struct NoneContext;

impl<T> Context<T, NoneContext> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains() {
        let e = io_err().with_context(|| "reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        let e2: Result<()> = None::<()>.context("missing key");
        assert_eq!(e2.unwrap_err().to_string(), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad bucket {} of {}", 3, 7);
        assert_eq!(e.to_string(), "bad bucket 3 of 7");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(f().unwrap_err().to_string(), "nope");
    }
}
