//! `cargo bench --bench hotpath` — micro-benchmarks of the coordinator's
//! hot paths (the §Perf targets in EXPERIMENTS.md):
//!
//!   * cpu_attn        — rust GQA attention kernel (the ω split's CPU side)
//!   * gather/scatter  — the module-batching boundary
//!   * kv_gather       — staging-window pack (HtoD engine job body)
//!   * dag_dp          — critical-path DP on a DeepSeek-sized DAG
//!   * search          — full decode strategy search
//!   * module_exec     — one expert_ffn execution on PJRT (needs artifacts)
//!
//! Hand-rolled harness (criterion unavailable offline): N timed iters,
//! reports min/mean.

use std::time::Instant;

use moe_gen::batching::{gather_rows, group_by_expert, scatter_add};
use moe_gen::cpu_attn::{decode_attention, Numerics, SeqAttn};
use moe_gen::kv::KvCache;
use moe_gen::sched::{self, Knobs, Scenario, Strategy};
use moe_gen::util::rng::Rng;
use moe_gen::{hw, model};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    f(); // warm-up
    let mut best = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        sum += dt;
    }
    println!(
        "bench: {name:<22} min {:>10.3} ms   mean {:>10.3} ms   ({iters} iters)",
        best * 1e3,
        sum / iters as f64 * 1e3
    );
}

fn main() {
    let mut rng = Rng::new(1);

    // -- cpu_attn: 64 seqs, ctx 128, 4 heads (tiny-MoE shape) ------------
    {
        let (nh, nkv, hd, len, b) = (4usize, 2usize, 16usize, 128usize, 64usize);
        let kvd = nkv * hd;
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..b)
            .map(|_| (rng.normal_vec(nh * hd), rng.normal_vec(len * kvd), rng.normal_vec(len * kvd)))
            .collect();
        let seqs: Vec<SeqAttn<'_>> =
            data.iter().map(|(q, k, v)| SeqAttn { q, k, v, len }).collect();
        let mut out = vec![Vec::new(); b];
        bench("cpu_attn_b64_ctx128", 50, || {
            decode_attention(&seqs, nh, nkv, hd, Numerics::Bf16Consistent, &mut out, 8);
        });
        bench("cpu_attn_1thread", 50, || {
            decode_attention(&seqs, nh, nkv, hd, Numerics::Bf16Consistent, &mut out, 1);
        });
    }

    // -- expert gather/scatter over a 4096-token accumulated batch ------
    {
        let (n, k, e, dim) = (4096usize, 2usize, 8usize, 64usize);
        let x = rng.normal_vec(n * dim);
        let idx: Vec<i32> = (0..n * k).map(|_| rng.below(e) as i32).collect();
        let w: Vec<f32> = (0..n * k).map(|_| 0.5f32).collect();
        bench("group_by_expert_4k", 100, || {
            let g = group_by_expert(&idx, &w, n, k, e);
            std::hint::black_box(g.len());
        });
        let groups = group_by_expert(&idx, &w, n, k, e);
        let mut acc = vec![0.0f32; n * dim];
        bench("gather_scatter_4k", 50, || {
            for g in &groups {
                let bucket = g.rows.len().next_power_of_two();
                let gathered = gather_rows(&x, dim, &g.rows, bucket);
                scatter_add(&mut acc, dim, &g.rows, &g.weights, &gathered);
            }
        });
    }

    // -- KV staging-window gather (128 seqs, cap 128) --------------------
    {
        let mut kv = KvCache::new(1, 2, 16, 128, 128);
        let slots: Vec<usize> = (0..128).map(|_| kv.alloc_slot().unwrap()).collect();
        let kvd = kv.kvd;
        for &s in &slots {
            kv.write_prefill(0, s, &rng.normal_vec(100 * kvd), &rng.normal_vec(100 * kvd));
            kv.set_len(s, 100);
        }
        let lens = vec![100usize; 128];
        bench("kv_gather_b128", 50, || {
            let k = kv.gather_side(0, &slots, &lens, 128, true);
            std::hint::black_box(k.len());
        });
    }

    // -- DAG DP on a DeepSeek-scale decode DAG ---------------------------
    {
        let scn = Scenario::new(model::deepseek_v2(), hw::c2(), 512, 256);
        let s = Strategy {
            b: 1024, b_a: 64, b_e: 8192, omega: 0.0,
            s_expert: 2 * scn.model.expert_bytes(), s_params: 0, reuse: 1.0,
        };
        let g = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen_gpu_only(), 3);
        println!("(dag nodes: {})", g.len());
        bench("dag_critical_path", 100, || {
            std::hint::black_box(g.critical_path());
        });
        bench("dag_simulate", 100, || {
            std::hint::black_box(g.simulate());
        });
        bench("dag_build_3layers", 50, || {
            let g = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen_gpu_only(), 3);
            std::hint::black_box(g.len());
        });
    }

    // -- full decode strategy search --------------------------------------
    {
        let scn = Scenario::new(model::mixtral_8x7b(), hw::c2(), 512, 256);
        bench("search_decode_8x7b", 5, || {
            std::hint::black_box(sched::search_decode(&scn, &Knobs::moe_gen()).throughput);
        });
        let scn2 = Scenario::new(model::deepseek_v2(), hw::c2(), 512, 256);
        bench("search_decode_dsv2", 3, || {
            std::hint::black_box(sched::search_decode(&scn2, &Knobs::moe_gen()).throughput);
        });
    }

    // -- live module exec (PJRT), if compiled in and artifacts present ----
    #[cfg(feature = "pjrt")]
    {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use moe_gen::runtime::{lit_f32, Runtime};
        let rt = Runtime::new("artifacts").expect("artifacts");
        let c = rt.cfg().clone();
        for &b in &[8usize, 128, 512] {
            let x = lit_f32(&vec![0.1f32; b * c.hidden_size], &[b, c.hidden_size]).unwrap();
            let wg = rt.weights.get("l0.e0.wg").unwrap();
            let wu = rt.weights.get("l0.e0.wu").unwrap();
            let wd = rt.weights.get("l0.e0.wd").unwrap();
            let spec = rt.artifacts.variant("expert_ffn", b).unwrap().clone();
            let _ = rt.execute(&spec, &[wg.as_ref(), wu.as_ref(), wd.as_ref(), &x]);
            bench(&format!("pjrt_expert_ffn_b{b}"), 30, || {
                let out = rt
                    .execute(&spec, &[wg.as_ref(), wu.as_ref(), wd.as_ref(), &x])
                    .unwrap();
                std::hint::black_box(out.len());
            });
            // §Perf optimization: device-cached weight buffers (S_Params)
            // + per-launch activation upload, vs re-copying weights each
            // execute.
            let (bg, _) = rt.weight_buffer("l0.e0.wg").unwrap();
            let (bu, _) = rt.weight_buffer("l0.e0.wu").unwrap();
            let (bd, _) = rt.weight_buffer("l0.e0.wd").unwrap();
            bench(&format!("pjrt_expert_cached_b{b}"), 30, || {
                let xb = rt.upload(&x).unwrap();
                let out = rt
                    .execute_b(&spec, &[bg.as_ref(), bu.as_ref(), bd.as_ref(), &xb])
                    .unwrap();
                std::hint::black_box(out.len());
            });
        }
    } else {
        println!("(pjrt module benches skipped: run `make artifacts`)");
    }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt module benches skipped: build with --features pjrt)");
}
