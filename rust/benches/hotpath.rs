//! `cargo bench --bench hotpath` — micro-benchmarks of the coordinator's
//! hot paths (the §Perf targets in EXPERIMENTS.md):
//!
//!   * cpu_attn          — rust GQA attention kernel (the ω split's CPU side)
//!   * grouped_batch     — counting-sort grouping of an accumulated batch
//!   * gather_scatter    — the legacy per-group batching boundary
//!   * grouped_vs_gather — grouped hot path vs legacy gather/scatter at
//!                         1K/4K/8K tokens; prints `speedup=` lines and
//!                         appends a machine-readable record per shape to
//!                         `BENCH_live.json` (the CI smoke step greps the
//!                         4K line and fails if grouped is slower)
//!   * kv_gather         — staging-window pack (HtoD engine job body)
//!   * dag_dp            — critical-path DP on a DeepSeek-sized DAG
//!   * search            — full decode strategy search
//!   * module_exec       — one expert_ffn execution on PJRT (needs artifacts)
//!
//! Hand-rolled harness (criterion unavailable offline): N timed iters,
//! reports min/mean. Positional args filter by substring, so
//! `cargo bench --bench hotpath -- grouped_vs_gather` runs one section.

use std::collections::BTreeMap;
use std::time::Instant;

use moe_gen::batching::{gather_rows, micro_batches, scatter_add, GroupedBatch};
use moe_gen::cpu_attn::{decode_attention, Numerics, SeqAttn};
use moe_gen::exec::TensorArena;
use moe_gen::kv::KvCache;
use moe_gen::runtime::RtConfig;
use moe_gen::sched::{self, Knobs, Scenario, Strategy};
use moe_gen::session::append_bench_record;
use moe_gen::util::json::Json;
use moe_gen::util::pick_bucket;
use moe_gen::util::rng::Rng;
use moe_gen::{hw, model};

fn enabled(filters: &[String], name: &str) -> bool {
    filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
}

/// Timed loop: returns (min, mean) seconds over `iters` after one warm-up.
fn time_secs<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64) {
    f(); // warm-up
    let mut best = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        sum += dt;
    }
    (best, sum / iters as f64)
}

fn bench<F: FnMut()>(filters: &[String], name: &str, iters: usize, f: F) {
    if !enabled(filters, name) {
        return;
    }
    let (best, mean) = time_secs(iters, f);
    println!(
        "bench: {name:<22} min {:>10.3} ms   mean {:>10.3} ms   ({iters} iters)",
        best * 1e3,
        mean * 1e3
    );
}

/// Random routed batch: `n` tokens × `k` distinct experts of `e`, with
/// normalized-ish weights — the shape the expert phase consumes.
fn routed_batch(rng: &mut Rng, n: usize, k: usize, e: usize) -> (Vec<i32>, Vec<f32>) {
    let mut idx = Vec::with_capacity(n * k);
    let mut w = Vec::with_capacity(n * k);
    for _ in 0..n {
        let a = rng.below(e);
        let mut b = rng.below(e);
        if b == a {
            b = (b + 1) % e;
        }
        idx.extend([a as i32, b as i32]);
        let wa = rng.f64() as f32 * 0.8 + 0.1;
        w.extend([wa, 1.0 - wa]);
    }
    (idx, w)
}

/// Legacy batching boundary: per-expert row lists, a fresh bucket-padded
/// gather per micro-batch, weighted scatter back (the pre-grouped hot
/// path this PR replaced — kept as the comparison baseline).
#[allow(deprecated, clippy::too_many_arguments)]
fn gather_scatter_wave(
    acc: &mut [f32],
    x: &[f32],
    idx: &[i32],
    w: &[f32],
    n: usize,
    k: usize,
    e: usize,
    dim: usize,
    micro: usize,
    buckets: &[usize],
) {
    for g in moe_gen::batching::group_by_expert(idx, w, n, k, e) {
        for r in micro_batches(g.rows.len(), micro) {
            let rows = &g.rows[r.clone()];
            let ws = &g.weights[r];
            let bucket = pick_bucket(rows.len(), buckets).expect("micro clamped to max bucket");
            let gathered = gather_rows(x, dim, rows, bucket);
            scatter_add(acc, dim, rows, ws, &gathered);
        }
    }
}

/// Grouped hot path: counting-sort permutation into a reused scratch
/// buffer, contiguous per-expert segments consumed zero-copy at full
/// buckets (pad copies only for sub-bucket tails), weighted scatter.
#[allow(clippy::too_many_arguments)]
fn grouped_wave(
    acc: &mut [f32],
    x: &[f32],
    idx: &[i32],
    w: &[f32],
    n: usize,
    k: usize,
    e: usize,
    dim: usize,
    micro: usize,
    buckets: &[usize],
    arena: &mut TensorArena,
) {
    let g = GroupedBatch::build(idx, w, n, k, e);
    let mut sorted = arena.take(n * k, dim);
    for (slot, &t) in g.perm.iter().enumerate() {
        sorted.row_mut(slot).copy_from_slice(&x[t * dim..(t + 1) * dim]);
    }
    for ex in 0..e {
        let seg = g.segment(ex);
        for r in micro_batches(seg.len(), micro) {
            let abs = seg.start + r.start..seg.start + r.end;
            let rows = &g.perm[abs.clone()];
            let ws = &g.weights[abs.clone()];
            let bucket = pick_bucket(rows.len(), buckets).expect("micro clamped to max bucket");
            if bucket == rows.len() {
                // Zero-copy: the segment slice *is* the kernel input.
                scatter_add(acc, dim, rows, ws, sorted.rows_slice(abs));
            } else {
                let mut pad = arena.take_zeroed(bucket, dim);
                pad.data[..rows.len() * dim].copy_from_slice(sorted.rows_slice(abs));
                scatter_add(acc, dim, rows, ws, &pad.data);
                arena.put(pad);
            }
        }
    }
    arena.put(sorted);
}

fn main() {
    let filters: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let mut rng = Rng::new(1);

    // -- cpu_attn: 64 seqs, ctx 128, 4 heads (tiny-MoE shape) ------------
    {
        let (nh, nkv, hd, len, b) = (4usize, 2usize, 16usize, 128usize, 64usize);
        let kvd = nkv * hd;
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..b)
            .map(|_| (rng.normal_vec(nh * hd), rng.normal_vec(len * kvd), rng.normal_vec(len * kvd)))
            .collect();
        let seqs: Vec<SeqAttn<'_>> =
            data.iter().map(|(q, k, v)| SeqAttn { q, k, v, len }).collect();
        let mut out = vec![Vec::new(); b];
        bench(&filters, "cpu_attn_b64_ctx128", 50, || {
            decode_attention(&seqs, nh, nkv, hd, Numerics::Bf16Consistent, &mut out, 8);
        });
        bench(&filters, "cpu_attn_1thread", 50, || {
            decode_attention(&seqs, nh, nkv, hd, Numerics::Bf16Consistent, &mut out, 1);
        });
    }

    // -- expert batching boundary over a 4096-token accumulated batch ----
    // Bucket geometry comes from the engine's own config: pick_bucket over
    // the tiny model's expert_buckets, micro-batched at the largest bucket
    // (an expert sees ~n*k/e ≈ 1024 rows here — above the 512 max).
    let c = RtConfig::tiny();
    let micro = *c.expert_buckets.last().unwrap();
    {
        let (n, k, e, dim) = (4096usize, 2usize, 8usize, c.hidden_size);
        let x = rng.normal_vec(n * dim);
        let (idx, w) = routed_batch(&mut rng, n, k, e);
        bench(&filters, "grouped_batch_build_4k", 100, || {
            let g = GroupedBatch::build(&idx, &w, n, k, e);
            std::hint::black_box(g.perm.len());
        });
        let mut acc = vec![0.0f32; n * dim];
        bench(&filters, "gather_scatter_4k", 50, || {
            gather_scatter_wave(&mut acc, &x, &idx, &w, n, k, e, dim, micro, &c.expert_buckets);
        });
    }

    // -- grouped hot path vs legacy gather/scatter across batch sizes ----
    // The tentpole's acceptance bench: one `speedup=` line per shape
    // (CI asserts grouped >= gather at n=4096) and one machine-readable
    // record per shape appended to the BENCH_live.json trajectory.
    if enabled(&filters, "grouped_vs_gather") {
        let (k, e, dim) = (2usize, 8usize, c.hidden_size);
        let mut arena = TensorArena::new();
        let bench_path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_live.json");
        for n in [1024usize, 4096, 8192] {
            let x = rng.normal_vec(n * dim);
            let (idx, w) = routed_batch(&mut rng, n, k, e);
            let mut acc = vec![0.0f32; n * dim];
            let iters = if n >= 8192 { 20 } else { 40 };
            let (_, gather_mean) = time_secs(iters, || {
                gather_scatter_wave(&mut acc, &x, &idx, &w, n, k, e, dim, micro, &c.expert_buckets);
            });
            let (_, grouped_mean) = time_secs(iters, || {
                grouped_wave(
                    &mut acc, &x, &idx, &w, n, k, e, dim, micro, &c.expert_buckets, &mut arena,
                );
            });
            let speedup = gather_mean / grouped_mean;
            println!(
                "bench: grouped_vs_gather n={n} gather {:>8.3} ms   grouped {:>8.3} ms   \
                 speedup={speedup:.3}",
                gather_mean * 1e3,
                grouped_mean * 1e3
            );
            let mut m = BTreeMap::new();
            m.insert("bench_name".into(), Json::Str("hotpath_grouped_vs_gather".into()));
            // Same-shape records compare across history under this key
            // (tools/perf_gate.py); append_bench_record stamps "git".
            m.insert(
                "config_key".into(),
                Json::Str(format!("bench/hotpath_grouped_vs_gather/n{n}")),
            );
            m.insert("n_tokens".into(), Json::Num(n as f64));
            m.insert("top_k".into(), Json::Num(k as f64));
            m.insert("num_experts".into(), Json::Num(e as f64));
            m.insert("gather_ms".into(), Json::Num(gather_mean * 1e3));
            m.insert("grouped_ms".into(), Json::Num(grouped_mean * 1e3));
            m.insert("speedup".into(), Json::Num(speedup));
            append_bench_record(&bench_path, Json::Obj(m));
        }
    }

    // -- KV staging-window gather (128 seqs, cap 128) --------------------
    {
        let mut kv = KvCache::new(1, 2, 16, 128, 128);
        let slots: Vec<usize> = (0..128).map(|_| kv.alloc_slot().unwrap()).collect();
        let kvd = kv.kvd;
        for &s in &slots {
            kv.write_prefill(0, s, &rng.normal_vec(100 * kvd), &rng.normal_vec(100 * kvd));
            kv.set_len(s, 100);
        }
        let lens = vec![100usize; 128];
        bench(&filters, "kv_gather_b128", 50, || {
            let k = kv.gather_side(0, &slots, &lens, 128, true);
            std::hint::black_box(k.len());
        });
    }

    // -- DAG DP on a DeepSeek-scale decode DAG ---------------------------
    if enabled(&filters, "dag") {
        let scn = Scenario::new(model::deepseek_v2(), hw::c2(), 512, 256);
        let s = Strategy {
            b: 1024, b_a: 64, b_e: 8192, omega: 0.0,
            s_expert: 2 * scn.model.expert_bytes(), s_params: 0, reuse: 1.0,
            n_devices: 1, placement: moe_gen::batching::ExpertPlacement::RoundRobin,
            replication_bytes: 0,
        };
        let g = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen_gpu_only(), 3);
        println!("(dag nodes: {})", g.len());
        bench(&filters, "dag_critical_path", 100, || {
            std::hint::black_box(g.critical_path());
        });
        bench(&filters, "dag_simulate", 100, || {
            std::hint::black_box(g.simulate());
        });
        bench(&filters, "dag_build_3layers", 50, || {
            let g = sched::build_decode_dag(&scn, &s, &Knobs::moe_gen_gpu_only(), 3);
            std::hint::black_box(g.len());
        });
    }

    // -- full decode strategy search --------------------------------------
    if enabled(&filters, "search") {
        let scn = Scenario::new(model::mixtral_8x7b(), hw::c2(), 512, 256);
        bench(&filters, "search_decode_8x7b", 5, || {
            std::hint::black_box(sched::search_decode(&scn, &Knobs::moe_gen()).throughput);
        });
        let scn2 = Scenario::new(model::deepseek_v2(), hw::c2(), 512, 256);
        bench(&filters, "search_decode_dsv2", 3, || {
            std::hint::black_box(sched::search_decode(&scn2, &Knobs::moe_gen()).throughput);
        });
    }

    // -- live module exec (PJRT), if compiled in and artifacts present ----
    #[cfg(feature = "pjrt")]
    {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use moe_gen::runtime::{lit_f32, Runtime};
        let rt = Runtime::new("artifacts").expect("artifacts");
        let c = rt.cfg().clone();
        for &b in &[8usize, 128, 512] {
            let x = lit_f32(&vec![0.1f32; b * c.hidden_size], &[b, c.hidden_size]).unwrap();
            let wg = rt.weights.get("l0.e0.wg").unwrap();
            let wu = rt.weights.get("l0.e0.wu").unwrap();
            let wd = rt.weights.get("l0.e0.wd").unwrap();
            let spec = rt.artifacts.variant("expert_ffn", b).unwrap().clone();
            let _ = rt.execute(&spec, &[wg.as_ref(), wu.as_ref(), wd.as_ref(), &x]);
            bench(&filters, &format!("pjrt_expert_ffn_b{b}"), 30, || {
                let out = rt
                    .execute(&spec, &[wg.as_ref(), wu.as_ref(), wd.as_ref(), &x])
                    .unwrap();
                std::hint::black_box(out.len());
            });
            // §Perf optimization: device-cached weight buffers (S_Params)
            // + per-launch activation upload, vs re-copying weights each
            // execute.
            let (bg, _) = rt.weight_buffer("l0.e0.wg").unwrap();
            let (bu, _) = rt.weight_buffer("l0.e0.wu").unwrap();
            let (bd, _) = rt.weight_buffer("l0.e0.wd").unwrap();
            bench(&filters, &format!("pjrt_expert_cached_b{b}"), 30, || {
                let xb = rt.upload(&x).unwrap();
                let out = rt
                    .execute_b(&spec, &[bg.as_ref(), bu.as_ref(), bd.as_ref(), &xb])
                    .unwrap();
                std::hint::black_box(out.len());
            });
        }
    } else {
        println!("(pjrt module benches skipped: run `make artifacts`)");
    }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt module benches skipped: build with --features pjrt)");
}
