//! `cargo bench --bench ablations` — live ablations of MoE-Gen's design
//! choices on the real PJRT path (paper §5.4 "Further Study" + the
//! DESIGN.md design-choice list):
//!
//!   * accumulated batch B     (insufficient-batch study, Table 9's axis)
//!   * attention micro-batch b_a (module asymmetry)
//!   * ω CPU-attention split     (Fig. 7's axis, live)
//!   * prefetch vs on-demand weight fetching (under a throttled link)
//!   * baseline micro-batch size (the unified batch the model-based and
//!     continuous baselines push through the whole model)
//!
//! Every row constructs its job through the typed [`JobSpec`] layer and
//! runs it through a [`Session`] — the same path the CLI uses — so the
//! ablated knobs are exactly the spec's public ones. Token streams are
//! checked for invariance across all ablations (greedy decode must not
//! depend on any of these knobs), and a final baseline row appends one
//! record to the repo-root `BENCH_live.json` perf trajectory.

use moe_gen::config::Policy;
use moe_gen::session::Session;
use moe_gen::spec::JobSpec;
use moe_gen::workload;

/// Base spec shared by every ablation row: live artifacts when present,
/// no trajectory spam from sweep rows (the dedicated baseline row at the
/// end records instead).
fn base_spec() -> JobSpec {
    let mut spec = JobSpec { bench_log: None, ..JobSpec::default() };
    spec.eng.artifacts_dir = "artifacts".into();
    spec
}

fn run(spec: JobSpec, prompts: &[Vec<i32>], steps: usize) -> (f64, f64, Vec<Vec<i32>>) {
    let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
    let t0 = std::time::Instant::now();
    let rep = s.run_prompts(prompts, steps).expect("ablation run");
    (t0.elapsed().as_secs_f64(), rep.decode_tp, rep.tokens)
}

fn main() {
    let prompts = workload::generate_prompts(48, 24, 64, 512, 3);
    let steps = 12;
    let mut reference: Option<Vec<Vec<i32>>> = None;
    fn check(reference: &mut Option<Vec<Vec<i32>>>, name: &str, toks: &Vec<Vec<i32>>) {
        match reference {
            None => *reference = Some(toks.clone()),
            Some(r) => assert_eq!(toks, r, "{name}: tokens changed under ablation"),
        }
    }

    println!("== ablation: accumulated batch B (max_batch) ==");
    for b in [4usize, 16, 48] {
        let mut spec = base_spec();
        spec.eng.max_batch = b;
        // Keep the spec valid: attention can never micro-batch more
        // sequences than the wave accumulates (validate rejects b_a > B).
        spec.eng.attn_micro = spec.eng.attn_micro.min(b);
        let (wall, dtp, toks) = run(spec, &prompts, steps);
        check(&mut reference, "max_batch", &toks);
        println!("bench: ablate_B_{b:<4}        wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
    }

    // b_a = 128 is omitted from the default sweep: on the PJRT-CPU
    // testbed the padded [128, ctx] staged window makes each attention
    // launch ~1.5 s (see hotpath bench), i.e. the exact pathology the
    // paper's search avoids by keeping b_a small.
    println!("\n== ablation: attention micro-batch b_a ==");
    for ba in [8usize, 16, 32] {
        let mut spec = base_spec();
        spec.eng.attn_micro = ba;
        spec.eng.max_batch = 48;
        let (wall, dtp, toks) = run(spec, &prompts, steps);
        check(&mut reference, "attn_micro", &toks);
        println!("bench: ablate_ba_{ba:<4}       wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
    }

    // ω moves sequences onto the bf16-consistent CPU kernel; the paper's
    // contract (App. B) is numerical *consistency*, not bitwise equality,
    // so greedy near-ties may flip. Report token agreement instead of
    // asserting exactness (must stay near 100%).
    println!("\n== ablation: ω CPU-attention split (live Fig. 7) ==");
    for omega in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
        let mut spec = base_spec();
        spec.eng.omega = omega;
        spec.eng.max_batch = 48;
        let (wall, dtp, toks) = run(spec, &prompts, steps);
        let r = reference.as_ref().unwrap();
        let total: usize = r.iter().map(|t| t.len()).sum();
        let agree: usize = r
            .iter()
            .zip(&toks)
            .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
            .sum();
        let pct = 100.0 * agree as f64 / total as f64;
        assert!(pct > 90.0, "omega={omega}: agreement collapsed to {pct:.1}%");
        println!(
            "bench: ablate_omega_{omega:<4} wall {wall:>7.2}s decode {dtp:>8.1} tok/s \
             agreement {pct:>5.1}%"
        );
    }

    println!("\n== ablation: prefetch vs on-demand (300 MB/s link) ==");
    for prefetch in [true, false] {
        let mut spec = base_spec();
        spec.eng.prefetch = prefetch;
        spec.eng.throttle_htod = Some(300e6);
        spec.eng.max_batch = 48;
        let (wall, dtp, toks) = run(spec, &prompts, steps);
        check(&mut reference, "prefetch", &toks);
        println!(
            "bench: ablate_prefetch_{:<5} wall {wall:>7.2}s decode {dtp:>8.1} tok/s",
            prefetch
        );
    }

    println!("\n== ablation: weight cache on/off (300 MB/s link) ==");
    for cache in [true, false] {
        let mut spec = base_spec();
        spec.eng.weight_cache_bytes = if cache { 256 << 20 } else { 0 };
        spec.eng.throttle_htod = Some(300e6);
        spec.eng.max_batch = 48;
        let (wall, dtp, toks) = run(spec, &prompts, steps);
        check(&mut reference, "weight_cache", &toks);
        println!(
            "bench: ablate_wcache_{:<5} wall {wall:>7.2}s decode {dtp:>8.1} tok/s",
            cache
        );
    }

    println!("\n== ablation: baseline micro-batch (continuous policy) ==");
    for micro in [4usize, 8, 16] {
        let mut spec = base_spec();
        spec.eng.policy = Policy::Continuous;
        spec.eng.baseline_micro_batch = micro;
        let (wall, dtp, toks) = run(spec, &prompts, steps);
        check(&mut reference, "baseline_micro_batch", &toks);
        println!("bench: ablate_micro_{micro:<4}     wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
    }

    println!("\n== ablation: expert-parallel n_devices (virtual topology) ==");
    for nd in [1usize, 2, 4] {
        let mut spec = base_spec();
        spec.eng.n_devices = nd;
        spec.eng.max_batch = 48;
        let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
        let t0 = std::time::Instant::now();
        let rep = s.run_prompts(&prompts, steps).expect("ablation run");
        let wall = t0.elapsed().as_secs_f64();
        check(&mut reference, "n_devices", &rep.tokens);
        let ici_ms = 1e3 * rep.timeline.busy(moe_gen::exec::Stream::Interconnect);
        if nd == 1 {
            assert_eq!(ici_ms, 0.0, "single device must not touch the interconnect");
        } else {
            assert!(ici_ms > 0.0, "nd={nd} moved no all-to-all bytes");
        }
        println!(
            "bench: ablate_ndev_{nd:<4}      wall {wall:>7.2}s decode {:>8.1} tok/s \
             ici {ici_ms:>7.3}ms",
            rep.decode_tp
        );
    }

    // One baseline row recorded into the perf trajectory (the sweep rows
    // above stay out of it on purpose — they ablate, they don't track).
    let mut spec = base_spec();
    spec.eng.max_batch = 48;
    spec.bench_log = Some(moe_gen::spec::default_bench_log());
    let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
    let t0 = std::time::Instant::now();
    let rep = s.run_prompts(&prompts, steps).expect("ablation run");
    let wall = t0.elapsed().as_secs_f64();
    check(&mut reference, "baseline_record", &rep.tokens);
    // The session stamps the record with config_key/git/roofline_fraction
    // (tools/perf_gate.py diffs consecutive same-key records).
    println!(
        "\nbench: baseline_B48          wall {wall:>7.2}s decode {:>8.1} tok/s \
         roofline {:>5.1}% (recorded to BENCH_live.json)",
        rep.decode_tp,
        100.0 * rep.roofline_fraction,
    );

    println!("\ntoken invariance across all ablations ✓");
}
