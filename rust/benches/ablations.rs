//! `cargo bench --bench ablations` — live ablations of MoE-Gen's design
//! choices on the real PJRT path (paper §5.4 "Further Study" + the
//! DESIGN.md design-choice list):
//!
//!   * accumulated batch B     (insufficient-batch study, Table 9's axis)
//!   * attention micro-batch b_a (module asymmetry)
//!   * ω CPU-attention split     (Fig. 7's axis, live)
//!   * prefetch vs on-demand weight fetching (under a throttled link)
//!   * baseline micro-batch size (the unified batch the model-based and
//!     continuous baselines push through the whole model)
//!
//! Each row is a full offline run on the tiny MoE; token streams are
//! checked for invariance across all ablations (greedy decode must not
//! depend on any of these knobs).

use moe_gen::config::EngineConfig;
use moe_gen::engine::Engine;
use moe_gen::workload;

fn run(cfg: EngineConfig, prompts: &[Vec<i32>], steps: usize) -> (f64, f64, Vec<Vec<i32>>) {
    let mut eng = Engine::new(cfg).expect("artifacts missing — run `make artifacts`");
    eng.warmup().unwrap();
    let t0 = std::time::Instant::now();
    let toks = eng.generate(prompts, steps).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    (wall, eng.metrics.decode_throughput(), toks)
}

fn main() {
    let prompts = workload::generate_prompts(48, 24, 64, 512, 3);
    let steps = 12;
    let base = EngineConfig { artifacts_dir: "artifacts".into(), ..EngineConfig::default() };
    let mut reference: Option<Vec<Vec<i32>>> = None;
    fn check(reference: &mut Option<Vec<Vec<i32>>>, name: &str, toks: &Vec<Vec<i32>>) {
        match reference {
            None => *reference = Some(toks.clone()),
            Some(r) => assert_eq!(toks, r, "{name}: tokens changed under ablation"),
        }
    }

    println!("== ablation: accumulated batch B (max_batch) ==");
    for b in [4usize, 16, 48] {
        let cfg = EngineConfig { max_batch: b, ..base.clone() };
        let (wall, dtp, toks) = run(cfg, &prompts, steps);
        check(&mut reference, "max_batch", &toks);
        println!("bench: ablate_B_{b:<4}        wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
    }

    // b_a = 128 is omitted from the default sweep: on the PJRT-CPU
    // testbed the padded [128, ctx] staged window makes each attention
    // launch ~1.5 s (see hotpath bench), i.e. the exact pathology the
    // paper's search avoids by keeping b_a small.
    println!("\n== ablation: attention micro-batch b_a ==");
    for ba in [8usize, 16, 32] {
        let cfg = EngineConfig { attn_micro: ba, max_batch: 48, ..base.clone() };
        let (wall, dtp, toks) = run(cfg, &prompts, steps);
        check(&mut reference, "attn_micro", &toks);
        println!("bench: ablate_ba_{ba:<4}       wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
    }

    // ω moves sequences onto the bf16-consistent CPU kernel; the paper's
    // contract (App. B) is numerical *consistency*, not bitwise equality,
    // so greedy near-ties may flip. Report token agreement instead of
    // asserting exactness (must stay near 100%).
    println!("\n== ablation: ω CPU-attention split (live Fig. 7) ==");
    for omega in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
        let cfg = EngineConfig { omega, max_batch: 48, ..base.clone() };
        let (wall, dtp, toks) = run(cfg, &prompts, steps);
        let r = reference.as_ref().unwrap();
        let total: usize = r.iter().map(|t| t.len()).sum();
        let agree: usize = r
            .iter()
            .zip(&toks)
            .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
            .sum();
        let pct = 100.0 * agree as f64 / total as f64;
        assert!(pct > 90.0, "omega={omega}: agreement collapsed to {pct:.1}%");
        println!(
            "bench: ablate_omega_{omega:<4} wall {wall:>7.2}s decode {dtp:>8.1} tok/s \
             agreement {pct:>5.1}%"
        );
    }

    println!("\n== ablation: prefetch vs on-demand (300 MB/s link) ==");
    for prefetch in [true, false] {
        let cfg = EngineConfig {
            prefetch,
            throttle_htod: Some(300e6),
            max_batch: 48,
            ..base.clone()
        };
        let (wall, dtp, toks) = run(cfg, &prompts, steps);
        check(&mut reference, "prefetch", &toks);
        println!(
            "bench: ablate_prefetch_{:<5} wall {wall:>7.2}s decode {dtp:>8.1} tok/s",
            prefetch
        );
    }

    println!("\n== ablation: weight cache on/off (300 MB/s link) ==");
    for cache in [true, false] {
        let cfg = EngineConfig {
            weight_cache_bytes: if cache { 256 << 20 } else { 0 },
            throttle_htod: Some(300e6),
            max_batch: 48,
            ..base.clone()
        };
        let (wall, dtp, toks) = run(cfg, &prompts, steps);
        check(&mut reference, "weight_cache", &toks);
        println!(
            "bench: ablate_wcache_{:<5} wall {wall:>7.2}s decode {dtp:>8.1} tok/s",
            cache
        );
    }

    println!("\n== ablation: baseline micro-batch (continuous policy) ==");
    for micro in [4usize, 8, 16] {
        let cfg = EngineConfig {
            policy: moe_gen::config::Policy::Continuous,
            baseline_micro_batch: micro,
            ..base.clone()
        };
        let rep = moe_gen::server::run_offline(cfg, &prompts, steps).unwrap();
        check(&mut reference, "baseline_micro_batch", &rep.tokens);
        println!(
            "bench: ablate_micro_{micro:<4}     wall {:>7.2}s decode {:>8.1} tok/s",
            rep.wall_secs, rep.decode_tp
        );
    }

    println!("\ntoken invariance across all ablations ✓");
}
