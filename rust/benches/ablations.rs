//! `cargo bench --bench ablations` — live ablations of MoE-Gen's design
//! choices on the real PJRT path (paper §5.4 "Further Study" + the
//! DESIGN.md design-choice list):
//!
//!   * accumulated batch B     (insufficient-batch study, Table 9's axis)
//!   * attention micro-batch b_a (module asymmetry)
//!   * ω CPU-attention split     (Fig. 7's axis, live)
//!   * prefetch vs on-demand weight fetching (under a throttled link)
//!   * baseline micro-batch size (the unified batch the model-based and
//!     continuous baselines push through the whole model)
//!   * sticky expert replication (fraction of S_Expert; DESIGN.md §14)
//!
//! Every row constructs its job through the typed [`JobSpec`] layer and
//! runs it through a [`Session`] — the same path the CLI uses — so the
//! ablated knobs are exactly the spec's public ones. Token streams are
//! checked for invariance across all ablations (greedy decode must not
//! depend on any of these knobs), and a final baseline row appends one
//! record to the repo-root `BENCH_live.json` perf trajectory.

use moe_gen::config::Policy;
use moe_gen::session::Session;
use moe_gen::spec::JobSpec;
use moe_gen::workload;

/// Base spec shared by every ablation row: live artifacts when present,
/// no trajectory spam from sweep rows (the dedicated baseline row at the
/// end records instead).
fn base_spec() -> JobSpec {
    let mut spec = JobSpec { bench_log: None, ..JobSpec::default() };
    spec.eng.artifacts_dir = "artifacts".into();
    spec
}

fn run(spec: JobSpec, prompts: &[Vec<i32>], steps: usize) -> (f64, f64, Vec<Vec<i32>>) {
    let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
    let t0 = std::time::Instant::now();
    let rep = s.run_prompts(prompts, steps).expect("ablation run");
    (t0.elapsed().as_secs_f64(), rep.decode_tp, rep.tokens)
}

/// Substring section filters, hotpath-bench style: `cargo bench --bench
/// ablations -- replication` runs only the matching sections (CI smokes
/// a single section this way); no args runs everything.
fn enabled(filters: &[String], name: &str) -> bool {
    filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
}

fn main() {
    let filters: Vec<String> = std::env::args().skip(1).collect();
    let prompts = workload::generate_prompts(48, 24, 64, 512, 3);
    let steps = 12;
    let mut reference: Option<Vec<Vec<i32>>> = None;
    fn check(reference: &mut Option<Vec<Vec<i32>>>, name: &str, toks: &Vec<Vec<i32>>) {
        match reference {
            None => *reference = Some(toks.clone()),
            Some(r) => assert_eq!(toks, r, "{name}: tokens changed under ablation"),
        }
    }

    if enabled(&filters, "max_batch") {
        println!("== ablation: accumulated batch B (max_batch) ==");
        for b in [4usize, 16, 48] {
            let mut spec = base_spec();
            spec.eng.max_batch = b;
            // Keep the spec valid: attention can never micro-batch more
            // sequences than the wave accumulates (validate rejects b_a > B).
            spec.eng.attn_micro = spec.eng.attn_micro.min(b);
            let (wall, dtp, toks) = run(spec, &prompts, steps);
            check(&mut reference, "max_batch", &toks);
            println!("bench: ablate_B_{b:<4}        wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
        }
    }

    // b_a = 128 is omitted from the default sweep: on the PJRT-CPU
    // testbed the padded [128, ctx] staged window makes each attention
    // launch ~1.5 s (see hotpath bench), i.e. the exact pathology the
    // paper's search avoids by keeping b_a small.
    if enabled(&filters, "attn_micro") {
        println!("\n== ablation: attention micro-batch b_a ==");
        for ba in [8usize, 16, 32] {
            let mut spec = base_spec();
            spec.eng.attn_micro = ba;
            spec.eng.max_batch = 48;
            let (wall, dtp, toks) = run(spec, &prompts, steps);
            check(&mut reference, "attn_micro", &toks);
            println!("bench: ablate_ba_{ba:<4}       wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
        }
    }

    // ω moves sequences onto the bf16-consistent CPU kernel; the paper's
    // contract (App. B) is numerical *consistency*, not bitwise equality,
    // so greedy near-ties may flip. Report token agreement instead of
    // asserting exactness (must stay near 100%).
    if enabled(&filters, "omega") {
        println!("\n== ablation: ω CPU-attention split (live Fig. 7) ==");
        for omega in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
            let mut spec = base_spec();
            spec.eng.omega = omega;
            spec.eng.max_batch = 48;
            let (wall, dtp, toks) = run(spec, &prompts, steps);
            let Some(r) = reference.as_ref() else {
                reference = Some(toks);
                continue;
            };
            let total: usize = r.iter().map(|t| t.len()).sum();
            let agree: usize = r
                .iter()
                .zip(&toks)
                .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
                .sum();
            let pct = 100.0 * agree as f64 / total as f64;
            assert!(pct > 90.0, "omega={omega}: agreement collapsed to {pct:.1}%");
            println!(
                "bench: ablate_omega_{omega:<4} wall {wall:>7.2}s decode {dtp:>8.1} tok/s \
                 agreement {pct:>5.1}%"
            );
        }
    }

    if enabled(&filters, "prefetch") {
        println!("\n== ablation: prefetch vs on-demand (300 MB/s link) ==");
        for prefetch in [true, false] {
            let mut spec = base_spec();
            spec.eng.prefetch = prefetch;
            spec.eng.throttle_htod = Some(300e6);
            spec.eng.max_batch = 48;
            let (wall, dtp, toks) = run(spec, &prompts, steps);
            check(&mut reference, "prefetch", &toks);
            println!(
                "bench: ablate_prefetch_{:<5} wall {wall:>7.2}s decode {dtp:>8.1} tok/s",
                prefetch
            );
        }
    }

    if enabled(&filters, "wcache") {
        println!("\n== ablation: weight cache on/off (300 MB/s link) ==");
        for cache in [true, false] {
            let mut spec = base_spec();
            spec.eng.weight_cache_bytes = if cache { 256 << 20 } else { 0 };
            spec.eng.throttle_htod = Some(300e6);
            spec.eng.max_batch = 48;
            let (wall, dtp, toks) = run(spec, &prompts, steps);
            check(&mut reference, "weight_cache", &toks);
            println!(
                "bench: ablate_wcache_{:<5} wall {wall:>7.2}s decode {dtp:>8.1} tok/s",
                cache
            );
        }
    }

    if enabled(&filters, "micro") {
        println!("\n== ablation: baseline micro-batch (continuous policy) ==");
        for micro in [4usize, 8, 16] {
            let mut spec = base_spec();
            spec.eng.policy = Policy::Continuous;
            spec.eng.baseline_micro_batch = micro;
            let (wall, dtp, toks) = run(spec, &prompts, steps);
            check(&mut reference, "baseline_micro_batch", &toks);
            println!("bench: ablate_micro_{micro:<4}     wall {wall:>7.2}s decode {dtp:>8.1} tok/s");
        }
    }

    if enabled(&filters, "ndev") {
        println!("\n== ablation: expert-parallel n_devices (virtual topology) ==");
        for nd in [1usize, 2, 4] {
            let mut spec = base_spec();
            spec.eng.n_devices = nd;
            spec.eng.max_batch = 48;
            let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
            let t0 = std::time::Instant::now();
            let rep = s.run_prompts(&prompts, steps).expect("ablation run");
            let wall = t0.elapsed().as_secs_f64();
            check(&mut reference, "n_devices", &rep.tokens);
            let ici_ms = 1e3 * rep.timeline.busy(moe_gen::exec::Stream::Interconnect);
            if nd == 1 {
                assert_eq!(ici_ms, 0.0, "single device must not touch the interconnect");
            } else {
                assert!(ici_ms > 0.0, "nd={nd} moved no all-to-all bytes");
            }
            println!(
                "bench: ablate_ndev_{nd:<4}      wall {wall:>7.2}s decode {:>8.1} tok/s \
                 ici {ici_ms:>7.3}ms",
                rep.decode_tp
            );
        }
    }

    // Replication rows are budgeted as a fraction of the strategy's
    // S_Expert, so they run through an explicit strategy (the spec path
    // that carries `replication_bytes`). A two-expert cache thrashes on
    // demand fetches, which is exactly where pinning cross-request-hot
    // experts pays; prefetch stays off so the lift is replication's
    // alone. Unlike the other sweeps these rows ARE recorded: the CI
    // smoke diffs their `expert_hit_rate` against the rep=0 row via the
    // `/rep{pct}` config-key suffix.
    if enabled(&filters, "replication") {
        println!("\n== ablation: sticky expert replication (fraction of S_Expert) ==");
        let probe = Session::open(base_spec()).expect("artifacts missing — run `make artifacts`");
        let per = probe.engine().weights.sizes.expert;
        drop(probe);
        let s_expert = 4 * per;
        let mut hit0 = None;
        for frac in [0.0f64, 0.25, 0.5] {
            let rep = (s_expert as f64 * frac) as usize;
            let mut spec = base_spec();
            spec.eng.max_batch = 48;
            spec.eng.prefetch = false;
            spec.bench_log = Some(moe_gen::spec::default_bench_log());
            spec.strategy = moe_gen::spec::StrategySource::Explicit {
                decode: moe_gen::sched::Strategy {
                    b: 48,
                    b_a: 8,
                    b_e: 512,
                    omega: 0.0,
                    s_expert,
                    s_params: 2 * per,
                    reuse: 1.0,
                    n_devices: 1,
                    placement: moe_gen::batching::ExpertPlacement::RoundRobin,
                    replication_bytes: rep,
                },
                prefill: None,
            };
            let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
            let t0 = std::time::Instant::now();
            let r = s.run_prompts(&prompts, steps).expect("ablation run");
            let wall = t0.elapsed().as_secs_f64();
            check(&mut reference, "replication", &r.tokens);
            match hit0 {
                None => hit0 = Some(r.expert_hit_rate),
                Some(base) => assert!(
                    r.expert_hit_rate > base,
                    "replication {frac} must lift expert hit-rate: {} vs {base}",
                    r.expert_hit_rate
                ),
            }
            println!(
                "bench: ablate_rep_{:<4}      wall {wall:>7.2}s decode {:>8.1} tok/s \
                 expert-hit {:>5.1}% (recorded to BENCH_live.json)",
                format!("{:.0}", 100.0 * frac),
                r.decode_tp,
                100.0 * r.expert_hit_rate,
            );
        }
    }

    // One baseline row recorded into the perf trajectory (the sweep rows
    // above stay out of it on purpose — they ablate, they don't track).
    if enabled(&filters, "baseline") {
        let mut spec = base_spec();
        spec.eng.max_batch = 48;
        spec.bench_log = Some(moe_gen::spec::default_bench_log());
        let mut s = Session::open(spec).expect("artifacts missing — run `make artifacts`");
        let t0 = std::time::Instant::now();
        let rep = s.run_prompts(&prompts, steps).expect("ablation run");
        let wall = t0.elapsed().as_secs_f64();
        check(&mut reference, "baseline_record", &rep.tokens);
        // The session stamps the record with config_key/git/roofline_fraction
        // (tools/perf_gate.py diffs consecutive same-key records).
        println!(
            "\nbench: baseline_B48          wall {wall:>7.2}s decode {:>8.1} tok/s \
             roofline {:>5.1}% (recorded to BENCH_live.json)",
            rep.decode_tp,
            100.0 * rep.roofline_fraction,
        );
    }

    println!("\ntoken invariance across all ablations ✓");
}
