//! `cargo bench --bench paper_tables` — regenerates every table and
//! figure of the paper's evaluation (the workload is the simulator +
//! strategy search itself), reports how long each takes, and writes a
//! machine-readable `BENCH_paper_tables.json` at the repo root so later
//! changes have a throughput trajectory to compare against. The live
//! block runs through the typed `JobSpec`/`Session` layer and therefore
//! also appends one record to the repo-root `BENCH_live.json` trajectory.
//!
//! Criterion is unavailable offline; this is a hand-rolled harness with
//! the same contract: timed, repeatable, machine-parseable lines.

use std::fmt::Write as _;
use std::time::Instant;

use moe_gen::sched::Scenario;
use moe_gen::session::Session;
use moe_gen::sim::{self, tables, System};
use moe_gen::spec::{JobSpec, WorkloadSpec};
use moe_gen::{hw, model};

fn bench_table(id: &str) -> (String, f64) {
    // Warm-up + 3 timed repetitions; report the minimum (least noise).
    let _ = tables::render(id);
    let mut best = f64::INFINITY;
    let mut out = String::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        out = tables::render(id);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    (out, best)
}

fn json_num(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x:.3}"),
        _ => "null".to_string(),
    }
}

/// Modeled throughput (tokens/s) per system for every paper scenario —
/// the perf-trajectory payload.
fn scenarios_json() -> String {
    let models = [
        model::mixtral_8x7b(),
        model::mixtral_8x22b(),
        model::deepseek_v2(),
        model::deepseek_r1(),
    ];
    let testbeds = [hw::c1(), hw::c2(), hw::c3()];
    let mut s = String::from("[");
    let mut first_scn = true;
    for m in &models {
        for h in &testbeds {
            let scn = Scenario::new(m.clone(), h.clone(), 512, 256);
            if !first_scn {
                s.push(',');
            }
            first_scn = false;
            let _ = write!(
                s,
                "\n    {{\"model\": \"{}\", \"testbed\": \"{}\", \"prompt\": 512, \"decode\": 256, \"systems\": {{",
                m.name,
                h.name.split(' ').next().unwrap_or(h.name.as_str())
            );
            let mut first_sys = true;
            for sys in System::table_order() {
                if !first_sys {
                    s.push_str(", ");
                }
                first_sys = false;
                let _ = write!(
                    s,
                    "\"{}\": {{\"decode_tps\": {}, \"prefill_tps\": {}}}",
                    sys.name(),
                    json_num(sim::decode_tp(&scn, sys)),
                    json_num(sim::prefill_tp(&scn, sys))
                );
            }
            s.push_str("}}");
        }
    }
    s.push_str("\n  ]");
    s
}

/// One small live run on the reference backend through the typed
/// spec/session layer: the weight-residency hit-rate and overlap land in
/// this file's `live` block, and `Session::run` appends the same run to
/// the repo-root `BENCH_live.json` trajectory.
fn live_json() -> String {
    let mut spec = JobSpec {
        workload: WorkloadSpec { num_requests: 12, mean_prompt: 16, max_prompt: 48, steps: 6 },
        ..JobSpec::default()
    };
    spec.eng.seed = 7;
    let t0 = Instant::now();
    let mut session = Session::open(spec).expect("session over the reference backend");
    let rep = session.run().expect("live run on the reference backend");
    format!(
        "{{\"backend\": \"ref-cpu\", \"sequences\": {}, \"steps\": 6, \
         \"decode_tps\": {:.3}, \"weight_cache_hit_rate\": {:.4}, \
         \"htod_overlap_fraction\": {:.4}, \"weight_evictions\": {}, \
         \"timeline_makespan_ms\": {:.3}, \"timeline_overlap_fraction\": {:.4}, \
         \"wall_ms\": {:.3}}}",
        rep.sequences,
        rep.decode_tp,
        rep.weight_hit_rate,
        rep.htod_overlap_fraction,
        rep.weight_evictions,
        rep.timeline.makespan_secs * 1e3,
        rep.timeline.overlap_fraction(),
        t0.elapsed().as_secs_f64() * 1e3,
    )
}

fn main() {
    let ids = ["1", "fig3", "fig4", "4", "5", "6", "7", "8", "9", "10", "fig7"];
    println!("== paper_tables bench: regenerating all evaluation tables ==\n");
    let mut total = 0.0;
    let mut render_ms = String::from("{");
    for (i, id) in ids.iter().enumerate() {
        let (out, secs) = bench_table(id);
        total += secs;
        println!("{out}");
        println!("bench: table_{id:<5} {:>10.3} ms\n", secs * 1e3);
        if i > 0 {
            render_ms.push_str(", ");
        }
        let _ = write!(render_ms, "\"{id}\": {:.3}", secs * 1e3);
    }
    render_ms.push('}');
    println!("bench: all_tables  {:>10.3} ms", total * 1e3);

    let json = format!(
        "{{\n  \"bench\": \"paper_tables\",\n  \"units\": {{\"decode_tps\": \"tokens/s\", \
         \"prefill_tps\": \"tokens/s\", \"table_render_ms\": \"ms\"}},\n  \
         \"scenarios\": {},\n  \"live\": {},\n  \"table_render_ms\": {render_ms},\n  \
         \"all_tables_ms\": {:.3}\n}}\n",
        scenarios_json(),
        live_json(),
        total * 1e3
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_paper_tables.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
