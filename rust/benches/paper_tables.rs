//! `cargo bench --bench paper_tables` — regenerates every table and
//! figure of the paper's evaluation (the workload is the simulator +
//! strategy search itself) and reports how long each takes.
//!
//! Criterion is unavailable offline; this is a hand-rolled harness with
//! the same contract: timed, repeatable, machine-parseable lines.

use std::time::Instant;

use moe_gen::sim::tables;

fn bench_table(id: &str) -> (String, f64) {
    // Warm-up + 3 timed repetitions; report the minimum (least noise).
    let _ = tables::render(id);
    let mut best = f64::INFINITY;
    let mut out = String::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        out = tables::render(id);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    (out, best)
}

fn main() {
    let ids = ["1", "fig3", "fig4", "4", "5", "6", "7", "8", "9", "10", "fig7"];
    println!("== paper_tables bench: regenerating all evaluation tables ==\n");
    let mut total = 0.0;
    for id in ids {
        let (out, secs) = bench_table(id);
        total += secs;
        println!("{out}");
        println!("bench: table_{id:<5} {:>10.3} ms\n", secs * 1e3);
    }
    println!("bench: all_tables  {:>10.3} ms", total * 1e3);
}
