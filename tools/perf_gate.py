#!/usr/bin/env python3
"""Perf-trajectory regression gate over a BENCH_live.json trajectory.

Records appended by ``session::append_bench_record`` carry a
``config_key`` (``{job}/{policy}/{strategy_source}/nd{n_devices}`` for
session runs, ``bench/...`` for standalone benches). Serve jobs with
non-default tenancy knobs (DESIGN.md §13) extend the key with ordered
suffixes so multi-tenant experiments gate against their own history
rather than the single-tenant trajectory:

* ``/slo{pct}`` — SLO-class scheduling on, with the latency-sensitive
  tenant fraction as a whole percentage (``/slo50`` = 50% mix);
* ``/dedup{pct}`` — shared-prefix KV dedup on, with the prefix-share
  fraction (``/dedup25`` = 25% of requests share the prefix);
* ``/pct{T}`` — chunked prefill at ``T`` prompt tokens per tick;
* ``/pc{N}`` — an explicit prefill wave width of ``N`` requests;
* ``/rep{pct}`` — sticky expert replication on (any job kind), with the
  replication budget as a whole percentage of the strategy's
  expert-prefetch reserve ``S_Expert`` (``/rep25`` = a quarter of the
  reserve pinned as cross-request-hot replicas; DESIGN.md §14). Always
  the last suffix.

e.g. ``serve/module/defaults/nd1/slo50/dedup50``. Knobs left at their
defaults add nothing, so pre-tenancy keys are unchanged. Only records
with the same key measure the same experiment, so the gate groups by
key and diffs the **newest record against the one before it**:

* throughput (first of ``total_tps``, ``decode_tps``, ``speedup``)
  dropping more than ``--max-regression`` (default 10%) fails;
* ``roofline_fraction`` dropping more than the same relative margin
  fails.

Keys with fewer than two records are reported and skipped — a freshly
seeded trajectory passes trivially until history accumulates.

Usage: tools/perf_gate.py [BENCH_live.json] [--max-regression 0.10]
"""

import argparse
import json
import sys

THROUGHPUT_FIELDS = ("total_tps", "decode_tps", "speedup")


def throughput_of(rec):
    for f in THROUGHPUT_FIELDS:
        v = rec.get(f)
        if isinstance(v, (int, float)) and v > 0:
            return f, float(v)
    return None, None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trajectory", nargs="?", default="BENCH_live.json")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="maximum tolerated relative drop (0.10 = 10%%)",
    )
    args = ap.parse_args()

    try:
        with open(args.trajectory) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"perf_gate: {args.trajectory} not found — nothing to gate")
        return 0
    runs = doc.get("runs")
    if not isinstance(runs, list):
        print(f"perf_gate: {args.trajectory} is not a bench trajectory", file=sys.stderr)
        return 1

    by_key = {}
    unkeyed = 0
    for rec in runs:
        if not isinstance(rec, dict):
            continue
        key = rec.get("config_key")
        if not key:
            unkeyed += 1
            continue
        by_key.setdefault(key, []).append(rec)

    floor = 1.0 - args.max_regression
    failures = []
    compared = 0
    for key in sorted(by_key):
        history = by_key[key]
        if len(history) < 2:
            print(f"perf_gate: {key}: only {len(history)} record(s), skipping")
            continue
        prev, new = history[-2], history[-1]
        field, prev_tp = throughput_of(prev)
        _, new_tp = throughput_of(new)
        if prev_tp and new_tp:
            compared += 1
            ratio = new_tp / prev_tp
            tag = "OK" if ratio >= floor else "FAIL"
            print(
                f"perf_gate: {key}: {field} {prev_tp:.1f} -> {new_tp:.1f} "
                f"({100 * (ratio - 1):+.1f}%) [{tag}]"
            )
            if ratio < floor:
                failures.append(
                    f"{key}: {field} regressed {100 * (1 - ratio):.1f}% "
                    f"({prev_tp:.1f} -> {new_tp:.1f}, git {prev.get('git')} -> {new.get('git')})"
                )
        prev_rf, new_rf = prev.get("roofline_fraction"), new.get("roofline_fraction")
        if isinstance(prev_rf, (int, float)) and isinstance(new_rf, (int, float)) and prev_rf > 0:
            if new_rf / prev_rf < floor:
                failures.append(
                    f"{key}: roofline_fraction dropped "
                    f"{100 * (1 - new_rf / prev_rf):.1f}% ({prev_rf:.4f} -> {new_rf:.4f})"
                )

    if unkeyed:
        print(f"perf_gate: {unkeyed} record(s) without config_key ignored")
    if failures:
        print(f"perf_gate: {len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"perf_gate:   {f}", file=sys.stderr)
        return 1
    print(f"perf_gate: pass ({compared} comparison(s), {len(by_key)} key(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
