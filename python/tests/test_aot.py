"""AOT artifact integrity: manifest completeness, golden consistency.

Skipped when artifacts/ has not been built (`make artifacts`).
"""

import json
import os

import numpy as np
import pytest

from compile.config import CONFIG

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_config_matches(manifest):
    c = manifest["config"]
    assert c["hidden_size"] == CONFIG.hidden_size
    assert c["num_experts"] == CONFIG.num_experts
    assert c["top_k"] == CONFIG.top_k
    assert tuple(c["token_buckets"]) == CONFIG.token_buckets
    assert tuple(c["expert_buckets"]) == CONFIG.expert_buckets


def test_all_module_files_exist_and_parse_as_hlo(manifest):
    assert len(manifest["modules"]) >= 25
    for m in manifest["modules"]:
        path = os.path.join(ART, m["file"])
        assert os.path.exists(path), m["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), m["file"]
        # Entry computation must declare every manifest parameter.
        assert text.count("parameter(") >= len(m["params"]), m["file"]


def test_every_bucket_lowered(manifest):
    by_name = {}
    for m in manifest["modules"]:
        by_name.setdefault(m["name"], []).append(m["meta"])
    for name in ("embed", "pre_attention", "post_attention", "router", "lm_head"):
        got = sorted(meta["tokens"] for meta in by_name[name])
        assert got == sorted(CONFIG.token_buckets), name
    got = sorted(meta["tokens"] for meta in by_name["expert_ffn"])
    assert got == sorted(CONFIG.expert_buckets)
    got = sorted(meta["batch"] for meta in by_name["attn_decode"])
    assert got == sorted(CONFIG.decode_batch_buckets)
    got = sorted(meta["batch"] for meta in by_name["attn_prefill"])
    assert got == sorted(CONFIG.prefill_batch_buckets)


def test_weights_npz_complete(manifest):
    w = np.load(os.path.join(ART, manifest["weights_file"]))
    assert "emb" in w and "lnf" in w and "lm_head" in w
    for layer in range(CONFIG.num_layers):
        for e in range(CONFIG.num_experts):
            assert f"l{layer}.e{e}.wg" in w
    assert w["emb"].shape == (CONFIG.vocab_size, CONFIG.hidden_size)


def test_golden_trace_present_and_sane(manifest):
    g = np.load(os.path.join(ART, manifest["golden_file"]))
    toks = g["trace.tokens"]
    assert toks.shape[1] == 16
    assert toks.min() >= 0 and toks.max() < CONFIG.vocab_size
    assert g["trace.lens"].shape[0] == toks.shape[0]


def test_golden_module_pairs_present(manifest):
    g = np.load(os.path.join(ART, manifest["golden_file"]))
    names = set(k.split(".")[1] for k in g.files if k.startswith("g."))
    for mod in ("embed", "pre_attention", "attn_prefill", "attn_decode",
                "post_attention", "router", "expert_ffn", "lm_head"):
        assert mod in names, mod


def test_golden_regeneration_deterministic(manifest):
    """Weights in npz must equal a fresh init (same seed) — guards drift."""
    from compile import model
    w_new = model.init_weights(CONFIG, seed=0)
    w_old = np.load(os.path.join(ART, manifest["weights_file"]))
    np.testing.assert_allclose(
        np.asarray(w_new["l0.wq"]), w_old["l0.wq"], rtol=0, atol=0)
