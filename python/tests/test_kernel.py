"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes; every case asserts allclose against
ref.py. Kernels run under interpret=True (the same lowering the AOT HLO
artifacts embed).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention
from compile.kernels.expert import expert_ffn
from compile.kernels.ref import (
    attention_ref,
    expert_ffn_ref,
    rmsnorm_ref,
    rope_ref,
    router_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Expert FFN kernel
# ---------------------------------------------------------------------------


class TestExpertKernel:
    @pytest.mark.parametrize("m,h,inter", [(8, 64, 128), (32, 64, 128), (128, 32, 64)])
    def test_matches_ref(self, m, h, inter):
        rng = np.random.default_rng(0)
        x, wg, wu, wd = rand(rng, m, h), rand(rng, h, inter), rand(rng, h, inter), rand(rng, inter, h)
        got = expert_ffn(x, wg, wu, wd)
        want = expert_ffn_ref(x, wg, wu, wd)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 64, 96]),
        h=st.sampled_from([16, 32, 64]),
        inter=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, m, h, inter, seed):
        rng = np.random.default_rng(seed)
        x, wg, wu, wd = rand(rng, m, h), rand(rng, h, inter), rand(rng, h, inter), rand(rng, inter, h)
        got = expert_ffn(x, wg, wu, wd)
        want = expert_ffn_ref(x, wg, wu, wd)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_bf16_inputs_accumulate_f32(self):
        rng = np.random.default_rng(1)
        x = rand(rng, 32, 64).astype(jnp.bfloat16)
        wg, wu, wd = (rand(rng, 64, 128).astype(jnp.bfloat16),
                      rand(rng, 64, 128).astype(jnp.bfloat16),
                      rand(rng, 128, 64).astype(jnp.bfloat16))
        got = expert_ffn(x, wg, wu, wd)
        assert got.dtype == jnp.float32
        want = expert_ffn_ref(x, wg, wu, wd)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    def test_block_tiling_invariance(self):
        """Result must not depend on the chosen block shapes."""
        rng = np.random.default_rng(2)
        x, wg, wu, wd = rand(rng, 64, 32), rand(rng, 32, 128), rand(rng, 32, 128), rand(rng, 128, 32)
        a = expert_ffn(x, wg, wu, wd, block_m=64, block_i=128)
        b = expert_ffn(x, wg, wu, wd, block_m=8, block_i=16)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_single_token_row(self):
        rng = np.random.default_rng(3)
        x, wg, wu, wd = rand(rng, 8, 16), rand(rng, 16, 32), rand(rng, 16, 32), rand(rng, 32, 16)
        got = expert_ffn(x, wg, wu, wd, block_m=8)
        want = expert_ffn_ref(x, wg, wu, wd)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------


class TestAttentionKernel:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("b,sq,skv,nh,nkv,hd", [
        (2, 32, 32, 4, 2, 16),
        (1, 64, 64, 4, 4, 16),
        (4, 16, 64, 8, 2, 8),
    ])
    def test_matches_ref(self, b, sq, skv, nh, nkv, hd, causal):
        rng = np.random.default_rng(0)
        q = rand(rng, b, sq, nh, hd)
        k = rand(rng, b, skv, nkv, hd)
        v = rand(rng, b, skv, nkv, hd)
        lens = rng.integers(1, skv + 1, size=b).astype(np.int32)
        got = flash_attention(q, k, v, lens, causal=causal)
        want = attention_ref(q, k, v, lens, causal=causal)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4]),
        sq=st.sampled_from([16, 32, 64]),
        skv=st.sampled_from([32, 64, 128]),
        heads=st.sampled_from([(4, 2), (4, 4), (8, 2)]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, sq, skv, heads, causal, seed):
        nh, nkv = heads
        hd = 16
        rng = np.random.default_rng(seed)
        q = rand(rng, b, sq, nh, hd)
        k = rand(rng, b, skv, nkv, hd)
        v = rand(rng, b, skv, nkv, hd)
        lens = rng.integers(0, skv + 1, size=b).astype(np.int32)
        got = flash_attention(q, k, v, lens, causal=causal)
        want = attention_ref(q, k, v, lens, causal=causal)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_zero_length_rows_are_zero(self):
        """Fully masked sequences (pad rows) must yield 0, never NaN."""
        rng = np.random.default_rng(1)
        q = rand(rng, 2, 16, 4, 16)
        k = rand(rng, 2, 32, 2, 16)
        v = rand(rng, 2, 32, 2, 16)
        lens = np.array([0, 16], dtype=np.int32)
        got = np.asarray(flash_attention(q, k, v, lens, causal=False))
        assert np.all(np.isfinite(got))
        np.testing.assert_array_equal(got[0], np.zeros_like(got[0]))

    def test_decode_single_position(self):
        """sq=1 (decode) against a staged cache with varying lengths."""
        rng = np.random.default_rng(2)
        b, S, nh, nkv, hd = 4, 128, 4, 2, 16
        q = rand(rng, b, 1, nh, hd)
        k = rand(rng, b, S, nkv, hd)
        v = rand(rng, b, S, nkv, hd)
        lens = np.array([1, 7, 64, 128], dtype=np.int32)
        got = flash_attention(q, k, v, lens, causal=False, block_q=1)
        want = attention_ref(q, k, v, lens, causal=False)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_block_tiling_invariance(self):
        rng = np.random.default_rng(3)
        q = rand(rng, 2, 64, 4, 16)
        k = rand(rng, 2, 64, 2, 16)
        v = rand(rng, 2, 64, 2, 16)
        lens = np.array([64, 33], dtype=np.int32)
        a = flash_attention(q, k, v, lens, causal=True, block_q=64, block_kv=64)
        b_ = flash_attention(q, k, v, lens, causal=True, block_q=16, block_kv=16)
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5)

    def test_causal_first_position_attends_self_only(self):
        rng = np.random.default_rng(4)
        b, s, nh, nkv, hd = 1, 32, 4, 2, 16
        q = rand(rng, b, s, nh, hd)
        k = rand(rng, b, s, nkv, hd)
        v = rand(rng, b, s, nkv, hd)
        lens = np.array([s], dtype=np.int32)
        got = np.asarray(flash_attention(q, k, v, lens, causal=True))
        # Position 0 attends only to kv position 0 -> output == v[0] per head
        group = nh // nkv
        for h in range(nh):
            np.testing.assert_allclose(
                got[0, 0, h], v[0, 0, h // group], rtol=1e-5, atol=1e-6
            )


# ---------------------------------------------------------------------------
# Shared math helpers (used by both ref and model)
# ---------------------------------------------------------------------------


class TestSharedMath:
    def test_rmsnorm_unit_variance(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 16, 64) * 10.0
        w = np.ones(64, dtype=np.float32)
        y = np.asarray(rmsnorm_ref(x, w))
        rms = np.sqrt((y ** 2).mean(axis=-1))
        np.testing.assert_allclose(rms, np.ones(16), rtol=1e-3)

    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(1)
        x = rand(rng, 8, 4, 16)
        pos = np.arange(8, dtype=np.int32)
        y = np.asarray(rope_ref(x, pos))
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_rope_position_zero_identity(self):
        rng = np.random.default_rng(2)
        x = rand(rng, 4, 2, 16)
        pos = np.zeros(4, dtype=np.int32)
        np.testing.assert_allclose(np.asarray(rope_ref(x, pos)), x, rtol=1e-6)

    def test_rope_relative_shift_invariance(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        rng = np.random.default_rng(3)
        q = rand(rng, 1, 1, 16)
        k = rand(rng, 1, 1, 16)
        def dot(i, j):
            qi = np.asarray(rope_ref(q, np.array([i], np.int32)))
            kj = np.asarray(rope_ref(k, np.array([j], np.int32)))
            return float((qi * kj).sum())
        np.testing.assert_allclose(dot(5, 3), dot(9, 7), rtol=1e-4)

    def test_router_weights_normalized(self):
        rng = np.random.default_rng(4)
        x = rand(rng, 32, 64)
        wr = rand(rng, 64, 8)
        idx, w = router_ref(x, wr, 2)
        assert idx.shape == (32, 2) and w.shape == (32, 2)
        np.testing.assert_allclose(np.asarray(w).sum(-1), np.ones(32), rtol=1e-5)
        # top-1 weight >= top-2 weight
        w = np.asarray(w)
        assert np.all(w[:, 0] >= w[:, 1] - 1e-7)

    def test_router_indices_distinct(self):
        rng = np.random.default_rng(5)
        x = rand(rng, 64, 32)
        wr = rand(rng, 32, 8)
        idx, _ = router_ref(x, wr, 2)
        idx = np.asarray(idx)
        assert np.all(idx[:, 0] != idx[:, 1])
