"""Module contracts for the L2 module-split model + reference engine."""

import jax
import numpy as np
import pytest

from compile import model
from compile.config import TinyMoEConfig
from compile.engine_ref import ReferenceEngine, pick_bucket
from compile.kernels.ref import attention_ref, expert_ffn_ref, rmsnorm_ref, rope_ref

jax.config.update("jax_platform_name", "cpu")

CFG = TinyMoEConfig()


@pytest.fixture(scope="module")
def weights():
    return model.init_weights(CFG, seed=0)


def rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


class TestModuleShapes:
    def test_embed(self, weights):
        ids = np.array([1, 2, 3, 4, 5, 6, 7, 8], np.int32)
        (x,) = model.embed(CFG, weights["emb"], ids)
        assert x.shape == (8, CFG.hidden_size)
        np.testing.assert_allclose(x[0], weights["emb"][1])

    def test_pre_attention(self, weights):
        rng = np.random.default_rng(0)
        x = rand(rng, 8, CFG.hidden_size)
        pos = np.arange(8, dtype=np.int32)
        q, k, v = model.pre_attention(
            CFG, weights["l0.ln1"], weights["l0.wq"], weights["l0.wk"],
            weights["l0.wv"], x, pos)
        assert q.shape == (8, CFG.num_heads, CFG.head_dim)
        assert k.shape == (8, CFG.num_kv_heads, CFG.head_dim)
        assert v.shape == (8, CFG.num_kv_heads, CFG.head_dim)
        # v gets no rope: check against direct projection
        xn = rmsnorm_ref(x, weights["l0.ln1"], CFG.rms_eps)
        v_want = (xn @ weights["l0.wv"]).reshape(8, CFG.num_kv_heads, CFG.head_dim)
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_want), rtol=1e-5)

    def test_attn_prefill_matches_dense_ref(self, weights):
        rng = np.random.default_rng(1)
        b, s = 2, CFG.prefill_seq
        q = rand(rng, b, s, CFG.num_heads, CFG.head_dim)
        k = rand(rng, b, s, CFG.num_kv_heads, CFG.head_dim)
        v = rand(rng, b, s, CFG.num_kv_heads, CFG.head_dim)
        lens = np.array([s, 17], np.int32)
        (ctx,) = model.attn_prefill(CFG, q, k, v, lens)
        want = attention_ref(q, k, v, lens, causal=True).reshape(b, s, CFG.q_dim)
        np.testing.assert_allclose(np.asarray(ctx), np.asarray(want), rtol=1e-4, atol=1e-5)

    def test_attn_decode_matches_dense_ref(self, weights):
        rng = np.random.default_rng(2)
        b, S = 8, CFG.max_context
        q = rand(rng, b, CFG.num_heads, CFG.head_dim)
        kc = rand(rng, b, S, CFG.num_kv_heads, CFG.head_dim)
        vc = rand(rng, b, S, CFG.num_kv_heads, CFG.head_dim)
        lens = rng.integers(1, S, size=b).astype(np.int32)
        (ctx,) = model.attn_decode(CFG, q, kc, vc, lens)
        want = attention_ref(q[:, None], kc, vc, lens, causal=False)[:, 0]
        np.testing.assert_allclose(
            np.asarray(ctx), np.asarray(want).reshape(b, CFG.q_dim),
            rtol=1e-4, atol=1e-5)

    def test_router_contract(self, weights):
        rng = np.random.default_rng(3)
        x = rand(rng, 32, CFG.hidden_size)
        xn, idx, w = model.router(CFG, weights["l0.ln2"], weights["l0.wr"], x)
        assert xn.shape == x.shape
        assert idx.shape == (32, CFG.top_k)
        idx, w = np.asarray(idx), np.asarray(w)
        assert idx.min() >= 0 and idx.max() < CFG.num_experts
        np.testing.assert_allclose(w.sum(-1), np.ones(32), rtol=1e-5)

    def test_expert_ffn_matches_ref(self, weights):
        rng = np.random.default_rng(4)
        x = rand(rng, 8, CFG.hidden_size)
        (y,) = model.expert_ffn(
            CFG, weights["l0.e0.wg"], weights["l0.e0.wu"], weights["l0.e0.wd"], x)
        want = expert_ffn_ref(x, weights["l0.e0.wg"], weights["l0.e0.wu"],
                              weights["l0.e0.wd"])
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-5)

    def test_lm_head_greedy(self, weights):
        rng = np.random.default_rng(5)
        x = rand(rng, 8, CFG.hidden_size)
        (ids,) = model.lm_head(CFG, weights["lnf"], weights["lm_head"], x)
        assert ids.shape == (8,) and ids.dtype == np.int32
        xn = rmsnorm_ref(x, weights["lnf"], CFG.rms_eps)
        want = np.argmax(np.asarray(xn @ weights["lm_head"]), axis=-1)
        np.testing.assert_array_equal(np.asarray(ids), want)

    def test_post_attention_residual(self, weights):
        rng = np.random.default_rng(6)
        ctx = rand(rng, 8, CFG.q_dim)
        resid = rand(rng, 8, CFG.hidden_size)
        (x,) = model.post_attention(CFG, weights["l0.wo"], ctx, resid)
        np.testing.assert_allclose(
            np.asarray(x), resid + ctx @ weights["l0.wo"], rtol=1e-5)


class TestBuckets:
    def test_pick_bucket_smallest_geq(self):
        assert pick_bucket(1, (8, 32, 128)) == 8
        assert pick_bucket(8, (8, 32, 128)) == 8
        assert pick_bucket(9, (8, 32, 128)) == 32
        assert pick_bucket(128, (8, 32, 128)) == 128

    def test_pick_bucket_overflow_raises(self):
        with pytest.raises(ValueError):
            pick_bucket(129, (8, 32, 128))


class TestReferenceEngine:
    def test_trace_shape_and_range(self, weights):
        eng = ReferenceEngine(CFG, weights)
        toks = eng.generate([[1, 2, 3], [4, 5, 6, 7, 8]], steps=4)
        assert toks.shape == (2, 4)
        assert toks.min() >= 0 and toks.max() < CFG.vocab_size

    def test_trace_deterministic(self, weights):
        e1 = ReferenceEngine(CFG, weights)
        e2 = ReferenceEngine(CFG, weights)
        prompts = [[10, 20, 30, 40], [7]]
        np.testing.assert_array_equal(
            e1.generate(prompts, 5), e2.generate(prompts, 5))

    def test_prefill_result_independent_of_batch_padding(self, weights):
        """A sequence's first token must not depend on its batch companions."""
        eng = ReferenceEngine(CFG, weights)
        solo = eng.generate([[11, 12, 13, 14, 15]], steps=3)
        batch = eng.generate([[11, 12, 13, 14, 15], [9, 8, 7]], steps=3)
        np.testing.assert_array_equal(solo[0], batch[0])

    def test_kv_cache_populated_only_to_len(self, weights):
        eng = ReferenceEngine(CFG, weights)
        caches, lens, _ = eng.prefill([[1, 2, 3, 4]])
        kc, vc = caches[0]
        assert np.any(kc[0, :4] != 0)
        np.testing.assert_array_equal(kc[0, 4:], np.zeros_like(kc[0, 4:]))

    def test_decode_extends_lens(self, weights):
        eng = ReferenceEngine(CFG, weights)
        caches, lens, toks = eng.prefill([[1, 2, 3]])
        l0 = lens.copy()
        eng.decode_step(caches, lens, toks)
        np.testing.assert_array_equal(lens, l0 + 1)
