"""L2: the MoE model as *separately lowered modules* (module-split).

Module-based batching (the paper's contribution) requires the coordinator
to launch attention and expert modules independently with different batch
sizes.  The model is therefore not one jitted function but a set of module
functions — each taking its weights as explicit parameters (so weight fetch
is an explicit, schedulable transfer on the rust side) and each lowered to
its own HLO artifact at several static batch buckets (see aot.py).

Module inventory (shapes use n = flat token count, b = sequence count):

  embed           (emb[V,H], ids[n]i32)                      -> x[n,H]
  pre_attention   (ln[H], wq, wk, wv, x[n,H], pos[n]i32)     -> q,k,v
  attn_prefill    (q[b,s,nh,hd], k, v [b,s,nkv,hd], lens[b]) -> ctx[b,s,nh*hd]
  attn_decode     (q[b,nh,hd], kc, vc [b,S,nkv,hd], lens[b]) -> ctx[b,nh*hd]
  post_attention  (wo, ctx[n,nh*hd], resid[n,H])             -> x[n,H]
  router          (ln2[H], wr[H,E], x[n,H])                  -> xn, idx, w
  expert_ffn      (wg, wu, wd, x[m,H])                       -> y[m,H]   (Pallas)
  lm_head         (lnf[H], wo[H,V], x[b,H])                  -> ids[b]i32

The weighted combine of expert outputs, residual adds between modules, and
all KV-cache management are deliberately *not* modules: they are the
coordinator's job (the gather/scatter across expert micro-batches IS
module-based batching) and run in rust on host memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import TinyMoEConfig
from .kernels.attention import flash_attention
from .kernels.expert import expert_ffn as expert_ffn_kernel
from .kernels.ref import rmsnorm_ref, rope_ref


# ---------------------------------------------------------------------------
# Module functions. Each returns a tuple (lowered with return_tuple=True).
# ---------------------------------------------------------------------------


def embed(cfg: TinyMoEConfig, emb: jax.Array, ids: jax.Array):
    """Token embedding lookup: (V,H), (n,)i32 -> (n,H)."""
    return (emb[ids],)


def pre_attention(
    cfg: TinyMoEConfig,
    ln: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    x: jax.Array,
    pos: jax.Array,
):
    """RMSNorm + QKV projection + RoPE over a flat token batch.

    x: (n, H), pos: (n,) absolute positions.
    Returns q (n, nh, hd), k (n, nkv, hd), v (n, nkv, hd).
    """
    n = x.shape[0]
    xn = rmsnorm_ref(x, ln, cfg.rms_eps)
    q = (xn @ wq).reshape(n, cfg.num_heads, cfg.head_dim)
    k = (xn @ wk).reshape(n, cfg.num_kv_heads, cfg.head_dim)
    v = (xn @ wv).reshape(n, cfg.num_kv_heads, cfg.head_dim)
    q = rope_ref(q, pos, cfg.rope_theta)
    k = rope_ref(k, pos, cfg.rope_theta)
    return q, k, v


def attn_prefill(
    cfg: TinyMoEConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lens: jax.Array,
):
    """Causal self-attention over padded prompts (Pallas flash kernel).

    q: (b, s, nh, hd); k, v: (b, s, nkv, hd); lens: (b,).
    Returns ctx (b, s, nh*hd).
    """
    b, s = q.shape[0], q.shape[1]
    ctx = flash_attention(q, k, v, lens, causal=True)
    return (ctx.reshape(b, s, cfg.q_dim),)


def attn_decode(
    cfg: TinyMoEConfig,
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lens: jax.Array,
):
    """Single-position attention against the staged KV cache (Pallas).

    q: (b, nh, hd); k_cache, v_cache: (b, S, nkv, hd); lens: (b,) where the
    current token's K/V are already appended (mask is kv_pos < len).
    Returns ctx (b, nh*hd).
    """
    b = q.shape[0]
    ctx = flash_attention(q[:, None], k_cache, v_cache, lens, causal=False)
    return (ctx[:, 0].reshape(b, cfg.q_dim),)


def post_attention(cfg: TinyMoEConfig, wo: jax.Array, ctx: jax.Array, resid: jax.Array):
    """Output projection + residual: (nh*hd,H), (n,nh*hd), (n,H) -> (n,H)."""
    return (resid + ctx @ wo,)


def topk_by_argmax(probs: jax.Array, k: int):
    """Top-k via k iterative argmax+mask rounds.

    Functionally identical to ``jax.lax.top_k`` (stable first-max tie
    break) but lowers to plain reduce/iota/select HLO — jax's native
    ``top_k`` emits a ``topk()`` HLO instruction that the pinned
    xla_extension 0.5.1 text parser cannot ingest.
    """
    n, e = probs.shape
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        v = jnp.max(p, axis=-1)
        vals.append(v)
        idxs.append(i)
        mask = jax.nn.one_hot(i, e, dtype=jnp.bool_)
        p = jnp.where(mask, -jnp.inf, p)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def router(cfg: TinyMoEConfig, ln2: jax.Array, wr: jax.Array, x: jax.Array):
    """Pre-MoE RMSNorm + top-k softmax router.

    Returns (xn (n,H) — normalized tokens the experts consume,
             idx (n,k) i32, weights (n,k) f32 renormalized).
    """
    xn = rmsnorm_ref(x, ln2, cfg.rms_eps)
    logits = xn @ wr
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = topk_by_argmax(probs, cfg.top_k)
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return xn, idx.astype(jnp.int32), weights


def expert_ffn(
    cfg: TinyMoEConfig,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    x: jax.Array,
):
    """One expert's SwiGLU FFN over its gathered micro-batch (Pallas)."""
    return (expert_ffn_kernel(x, wg, wu, wd),)


def lm_head(cfg: TinyMoEConfig, lnf: jax.Array, wo: jax.Array, x: jax.Array):
    """Final norm + greedy next-token: (b,H) -> ids (b,) i32."""
    xn = rmsnorm_ref(x, lnf, cfg.rms_eps)
    logits = xn @ wo
    return (jnp.argmax(logits, axis=-1).astype(jnp.int32),)


# ---------------------------------------------------------------------------
# Weight construction (shared by aot.py, goldens and tests).
# ---------------------------------------------------------------------------


def init_weights(cfg: TinyMoEConfig, seed: int = 0) -> dict:
    """Deterministic random init; flat dict keyed by artifact names."""
    key = jax.random.PRNGKey(seed)

    def nrm(key, shape, scale=0.05):
        return (scale * jax.random.normal(key, shape)).astype(jnp.float32)

    w = {}
    key, k0 = jax.random.split(key)
    w["emb"] = nrm(k0, (cfg.vocab_size, cfg.hidden_size), 0.1)
    for layer in range(cfg.num_layers):
        p = f"l{layer}."
        key, *ks = jax.random.split(key, 12)
        w[p + "ln1"] = jnp.ones(cfg.hidden_size, jnp.float32)
        w[p + "wq"] = nrm(ks[0], (cfg.hidden_size, cfg.q_dim))
        w[p + "wk"] = nrm(ks[1], (cfg.hidden_size, cfg.kv_dim))
        w[p + "wv"] = nrm(ks[2], (cfg.hidden_size, cfg.kv_dim))
        w[p + "wo"] = nrm(ks[3], (cfg.q_dim, cfg.hidden_size))
        w[p + "ln2"] = jnp.ones(cfg.hidden_size, jnp.float32)
        w[p + "wr"] = nrm(ks[4], (cfg.hidden_size, cfg.num_experts), 0.5)
        for e in range(cfg.num_experts):
            key, a, b, c = jax.random.split(key, 4)
            w[p + f"e{e}.wg"] = nrm(a, (cfg.hidden_size, cfg.ffn_inter))
            w[p + f"e{e}.wu"] = nrm(b, (cfg.hidden_size, cfg.ffn_inter))
            w[p + f"e{e}.wd"] = nrm(c, (cfg.ffn_inter, cfg.hidden_size))
        if cfg.use_shared_expert:
            key, a, b, c = jax.random.split(key, 4)
            w[p + "se.wg"] = nrm(a, (cfg.hidden_size, cfg.shared_inter))
            w[p + "se.wu"] = nrm(b, (cfg.hidden_size, cfg.shared_inter))
            w[p + "se.wd"] = nrm(c, (cfg.shared_inter, cfg.hidden_size))
    key, k1 = jax.random.split(key)
    w["lnf"] = jnp.ones(cfg.hidden_size, jnp.float32)
    w["lm_head"] = nrm(k1, (cfg.hidden_size, cfg.vocab_size), 0.1)
    return w
