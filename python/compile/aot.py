"""AOT pipeline: lower every model module to HLO text + emit artifacts.

Run once at build time (`make artifacts`); python is never on the request
path.  Outputs, all under ``artifacts/``:

  <module>_b<bucket>[_s<seq>].hlo.txt   one HLO text file per module per
                                        static batch bucket (HLO text, NOT
                                        serialized proto: jax >= 0.5 emits
                                        64-bit instruction ids that the xla
                                        crate's xla_extension 0.5.1 rejects;
                                        the text parser reassigns ids)
  manifest.json                         module -> file/params/outputs map +
                                        the full model config, consumed by
                                        rust/src/runtime/artifacts.rs
  weights.npz                           deterministic random weights
  golden.npz                            per-module input/output pairs and a
                                        full greedy-decode trace produced by
                                        the python ReferenceEngine, asserted
                                        against by rust integration tests
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import CONFIG, TinyMoEConfig
from .engine_ref import ReferenceEngine

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def module_variants(cfg: TinyMoEConfig):
    """Yield (name, bucket_meta, filename, [param specs with names])."""
    H, V, E = cfg.hidden_size, cfg.vocab_size, cfg.num_experts
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qd, kvd, I = cfg.q_dim, cfg.kv_dim, cfg.ffn_inter
    S = cfg.max_context

    for n in cfg.token_buckets:
        yield ("embed", {"tokens": n}, f"embed_b{n}",
               [("emb", spec((V, H))), ("ids", spec((n,), I32))])
        yield ("pre_attention", {"tokens": n}, f"pre_attention_b{n}",
               [("ln", spec((H,))), ("wq", spec((H, qd))),
                ("wk", spec((H, kvd))), ("wv", spec((H, kvd))),
                ("x", spec((n, H))), ("pos", spec((n,), I32))])
        yield ("post_attention", {"tokens": n}, f"post_attention_b{n}",
               [("wo", spec((qd, H))), ("ctx", spec((n, qd))),
                ("resid", spec((n, H)))])
        yield ("router", {"tokens": n}, f"router_b{n}",
               [("ln2", spec((H,))), ("wr", spec((H, E))),
                ("x", spec((n, H)))])
        yield ("lm_head", {"tokens": n}, f"lm_head_b{n}",
               [("lnf", spec((H,))), ("wo", spec((H, V))),
                ("x", spec((n, H)))])

    for m in cfg.expert_buckets:
        yield ("expert_ffn", {"tokens": m}, f"expert_ffn_b{m}",
               [("wg", spec((H, I))), ("wu", spec((H, I))),
                ("wd", spec((I, H))), ("x", spec((m, H)))])

    s = cfg.prefill_seq
    for b in cfg.prefill_batch_buckets:
        yield ("attn_prefill", {"batch": b, "seq": s},
               f"attn_prefill_b{b}_s{s}",
               [("q", spec((b, s, nh, hd))), ("k", spec((b, s, nkv, hd))),
                ("v", spec((b, s, nkv, hd))), ("lens", spec((b,), I32))])

    for b in cfg.decode_batch_buckets:
        yield ("attn_decode", {"batch": b, "kv_capacity": S},
               f"attn_decode_b{b}",
               [("q", spec((b, nh, hd))), ("kc", spec((b, S, nkv, hd))),
                ("vc", spec((b, S, nkv, hd))), ("lens", spec((b,), I32))])


def lower_all(cfg: TinyMoEConfig, out_dir: str) -> list:
    entries = []
    for name, meta, stem, params in module_variants(cfg):
        fn = functools.partial(getattr(model, name), cfg)
        lowered = jax.jit(fn).lower(*[s for _, s in params])
        text = to_hlo_text(lowered)
        fname = stem + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *[s for _, s in params])
        entries.append({
            "name": name,
            "meta": meta,
            "file": fname,
            "params": [
                {"name": pn, "shape": list(ps.shape), "dtype": ps.dtype.name}
                for pn, ps in params
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": o.dtype.name} for o in outs
            ],
        })
        print(f"  lowered {fname} ({len(text)} chars)")
    return entries


def make_goldens(cfg: TinyMoEConfig, weights: dict) -> dict:
    """Per-module golden input/output pairs + a full greedy trace."""
    rng = np.random.default_rng(1234)
    g = {}

    def sample(mod_name, bucket_args):
        fn = functools.partial(getattr(model, mod_name), cfg)
        args = []
        for (_, sp) in bucket_args:
            if sp.dtype == np.int32:
                hi = cfg.vocab_size if mod_name == "embed" else cfg.max_context // 2
                args.append(rng.integers(0, hi, sp.shape).astype(np.int32))
            else:
                args.append(rng.standard_normal(sp.shape).astype(np.float32))
        outs = jax.jit(fn)(*args)
        for i, a in enumerate(args):
            g[f"g.{mod_name}.in{i}"] = np.asarray(a)
        for i, o in enumerate(outs):
            g[f"g.{mod_name}.out{i}"] = np.asarray(o)

    chosen = {}
    for name, meta, stem, params in module_variants(cfg):
        # One golden per module, at its smallest bucket.
        if name not in chosen:
            chosen[name] = params
    for name, params in chosen.items():
        sample(name, params)

    # Full greedy-decode trace through the reference engine.
    prompts = [
        list(rng.integers(1, cfg.vocab_size, size=L).astype(int))
        for L in (5, 9, 16, 12)
    ]
    steps = 16
    engine = ReferenceEngine(cfg, weights)
    tokens = engine.generate(prompts, steps)

    maxlen = max(len(p) for p in prompts)
    pmat = np.zeros((len(prompts), maxlen), dtype=np.int32)
    for i, p in enumerate(prompts):
        pmat[i, : len(p)] = p
    g["trace.prompts"] = pmat
    g["trace.lens"] = np.array([len(p) for p in prompts], dtype=np.int32)
    g["trace.tokens"] = tokens.astype(np.int32)
    return g


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = CONFIG
    os.makedirs(args.out_dir, exist_ok=True)

    print("[aot] lowering modules to HLO text ...")
    entries = lower_all(cfg, args.out_dir)

    print("[aot] initializing weights ...")
    weights = {k: np.asarray(v) for k, v in model.init_weights(cfg, args.seed).items()}
    np.savez(os.path.join(args.out_dir, "weights.npz"), **weights)

    print("[aot] generating goldens (reference engine trace) ...")
    goldens = make_goldens(cfg, weights)
    np.savez(os.path.join(args.out_dir, "golden.npz"), **goldens)

    manifest = {
        "config": {
            "vocab_size": cfg.vocab_size,
            "hidden_size": cfg.hidden_size,
            "num_layers": cfg.num_layers,
            "num_heads": cfg.num_heads,
            "num_kv_heads": cfg.num_kv_heads,
            "head_dim": cfg.head_dim,
            "ffn_inter": cfg.ffn_inter,
            "num_experts": cfg.num_experts,
            "top_k": cfg.top_k,
            "use_shared_expert": cfg.use_shared_expert,
            "shared_inter": cfg.shared_inter,
            "rope_theta": cfg.rope_theta,
            "max_context": cfg.max_context,
            "rms_eps": cfg.rms_eps,
            "token_buckets": list(cfg.token_buckets),
            "expert_buckets": list(cfg.expert_buckets),
            "prefill_batch_buckets": list(cfg.prefill_batch_buckets),
            "prefill_seq": cfg.prefill_seq,
            "decode_batch_buckets": list(cfg.decode_batch_buckets),
        },
        "modules": entries,
        "weights_file": "weights.npz",
        "golden_file": "golden.npz",
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(entries)} HLO modules + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
