"""Configuration for the tiny MoE used on the live PJRT path.

The live model is a genuinely runnable MoE transformer with the same
*topology* as the paper's models (GQA attention + top-k router + SwiGLU
experts + optional DeepSeek-style shared expert), sized so that the PJRT CPU
client executes it quickly. Paper-scale models (Mixtral-8x7B, DeepSeek-V2,
...) are represented on the rust side as architecture descriptors for the
cost model; this config only describes the model that actually runs.

Shapes are static in HLO, so every module is lowered at a set of *batch
buckets*; the rust engine pads the live batch up to the nearest bucket
(the same trick CUDA-graph based serving systems use).
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class TinyMoEConfig:
    # Model architecture.
    vocab_size: int = 512
    hidden_size: int = 64
    num_layers: int = 2
    num_heads: int = 4
    num_kv_heads: int = 2          # GQA: 2 query heads per kv head
    head_dim: int = 16
    ffn_inter: int = 128           # expert intermediate size
    num_experts: int = 8
    top_k: int = 2
    use_shared_expert: bool = True # DeepSeek-style shared expert
    shared_inter: int = 128
    rope_theta: float = 10000.0
    max_context: int = 128         # decode KV-cache capacity (tokens/seq)
    rms_eps: float = 1e-5

    # Static-shape buckets. Flat-token modules (embed / pre_attention /
    # post_attention / router / lm_head) are lowered per token-count bucket;
    # expert_ffn per expert-batch bucket; attention per (batch, seq) bucket.
    token_buckets: Tuple[int, ...] = (8, 32, 128, 512)
    expert_buckets: Tuple[int, ...] = (8, 32, 128, 512)
    prefill_batch_buckets: Tuple[int, ...] = (1, 4, 16)
    prefill_seq: int = 64          # prompts are padded to this length
    decode_batch_buckets: Tuple[int, ...] = (8, 32, 128)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


CONFIG = TinyMoEConfig()
