"""Pallas flash-attention kernel (GQA, length-masked, optionally causal).

This is the L1 hot-spot for the attention module. One kernel serves both
phases of the paper's engine:

* prefill  — q has the full (padded) sequence, causal mask + length mask;
* decode   — q is a single position per sequence, length mask only (the
  current token's K/V have already been appended by the coordinator, the
  mask is ``kv_pos < length``).

TPU adaptation of the paper's CPU AVX kernel (see DESIGN.md
§Hardware-Adaptation): instead of L2-cache blocking we express the
HBM→VMEM schedule with BlockSpecs — K/V stream through VMEM in
``(block_kv, head_dim)`` tiles while an online-softmax accumulator lives in
the revisited output block.  The grid is ``(batch, q_head, q_tile,
kv_tile)`` with the kv axis innermost, so the running ``(m, l, acc)`` state
persists across kv tiles of a fixed query tile — the classic
flash-attention recurrence.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated through the interpreter and the same
HLO runs from rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() well-defined on
                 # fully-masked tiles (exp(-1e30 + 1e30) == 1, guarded below)


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    lens_ref,
    o_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_kv: int,
):
    qt = pl.program_id(2)
    kt = pl.program_id(3)

    @pl.when(kt == 0)
    def _init():
        # NEG_INF (not -inf) so that alpha = exp(m_prev - m_cur) is 1, not
        # inf, when the first tile is fully masked.
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    length = lens_ref[0]
    kv_pos = kt * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1
    )
    mask = kv_pos < length
    if causal:
        q_pos = qt * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0, 0]  # (bq,)
    l_prev = l_ref[0, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # Zero out masked lanes explicitly: on a *fully*-masked tile s == m_cur
    # == NEG_INF and exp(0) == 1 would otherwise pollute the accumulator.
    p = jnp.where(mask, p, 0.0)
    l_cur = alpha * l_prev + p.sum(axis=1)

    m_ref[0, 0] = m_cur
    l_ref[0, 0] = l_cur
    acc = o_ref[0, :, 0, :]
    o_ref[0, :, 0, :] = acc * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(kt == pl.num_programs(3) - 1)
    def _finalize():
        l_fin = l_ref[0, 0]
        # Rows with zero mass (padded query positions) stay 0 instead of NaN.
        l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
        o_ref[0, :, 0, :] = o_ref[0, :, 0, :] / l_safe[:, None]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    causal: bool,
    block_q: int = 32,
    block_kv: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """GQA flash attention.

    Args:
      q: (batch, sq, num_heads, head_dim)
      k, v: (batch, skv, num_kv_heads, head_dim)
      lengths: (batch,) int32 — valid kv length per sequence.
      causal: apply causal mask (prefill); decode uses length mask only.

    Returns:
      (batch, sq, num_heads, head_dim) float32.
    """
    b, sq, nh, hd = q.shape
    _, skv, nkv, _ = k.shape
    assert nh % nkv == 0, "query heads must be a multiple of kv heads"
    group = nh // nkv

    from .expert import largest_divisor_leq

    block_q = largest_divisor_leq(sq, block_q)
    block_kv = largest_divisor_leq(skv, block_kv)

    grid = (b, nh, sq // block_q, skv // block_kv)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
    )

    o, _m, _l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda bi, h, qt, kt: (bi, qt, h, 0)),
            pl.BlockSpec(
                (1, block_kv, 1, hd), lambda bi, h, qt, kt: (bi, kt, h // group, 0)
            ),
            pl.BlockSpec(
                (1, block_kv, 1, hd), lambda bi, h, qt, kt: (bi, kt, h // group, 0)
            ),
            pl.BlockSpec((1,), lambda bi, h, qt, kt: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda bi, h, qt, kt: (bi, qt, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, h, qt, kt: (bi, h, qt)),
            pl.BlockSpec((1, 1, block_q), lambda bi, h, qt, kt: (bi, h, qt)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sq, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
    return o
