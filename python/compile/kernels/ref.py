"""Pure-jnp oracles for the Pallas kernels and shared model math.

Everything here is straight-line jnp with no Pallas, no blocking and no
online-softmax trickery — the correctness ground truth the kernels (and,
transitively, the HLO artifacts the rust engine executes) are checked
against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_ref(x: jax.Array, pos: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding, rotate-half convention.

    x: (n, heads, head_dim), pos: (n,) int32.
    """
    n, h, hd = x.shape
    half = hd // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None] * inv_freq[None, :]  # (n, half)
    cos = jnp.cos(ang)[:, None, :]  # (n, 1, half)
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    causal: bool,
) -> jax.Array:
    """Dense GQA attention oracle.

    q: (b, sq, nh, hd); k, v: (b, skv, nkv, hd); lengths: (b,).
    Returns (b, sq, nh, hd) f32. Fully-masked query rows return 0.
    """
    b, sq, nh, hd = q.shape
    _, skv, nkv, _ = k.shape
    group = nh // nkv
    # Expand kv heads to query heads.
    k = jnp.repeat(k.astype(jnp.float32), group, axis=2)
    v = jnp.repeat(v.astype(jnp.float32), group, axis=2)
    q = q.astype(jnp.float32)

    scale = 1.0 / (hd ** 0.5)
    # (b, nh, sq, skv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    kv_pos = jnp.arange(skv)[None, None, None, :]
    mask = kv_pos < lengths[:, None, None, None]
    if causal:
        q_pos = jnp.arange(sq)[None, None, :, None]
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    p = p / denom
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def expert_ffn_ref(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array
) -> jax.Array:
    """SwiGLU FFN oracle: down( silu(x@gate) * (x@up) )."""
    x = x.astype(jnp.float32)
    g = x @ w_gate.astype(jnp.float32)
    u = x @ w_up.astype(jnp.float32)
    return (jax.nn.silu(g) * u) @ w_down.astype(jnp.float32)


def router_ref(x: jax.Array, w_router: jax.Array, top_k: int):
    """Top-k softmax router with renormalized weights (Mixtral-style)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return idx.astype(jnp.int32), weights
