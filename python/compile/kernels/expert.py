"""Pallas SwiGLU expert-FFN kernel — the throughput hot-spot of the paper.

Computes ``down( silu(x @ gate) * (x @ up) )`` for one expert over a large
accumulated token batch.  Module-based batching exists precisely to feed
this kernel ≥2^10 tokens at a time (paper Fig. 3), so the kernel is written
to scale with the token dimension.

TPU schedule (DESIGN.md §Hardware-Adaptation): grid is
``(m_tiles, i_tiles)`` — token tiles × intermediate-dim tiles.  The three
weight matrices stream through VMEM in ``block_i``-wide stripes, targeting
128-wide MXU tiles at real model dims; the output block has a constant
index along the ``i`` axis, so it is revisited and serves as the f32
accumulator (``o += silu(x@Wg_i) * (x@Wu_i) @ Wd_i``), the standard
K-blocked matmul recurrence with no scratch required.

``interpret=True`` — see attention.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (block-shape snapping)."""
    cap = min(cap, n)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def _expert_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)        # (bm, H)
    g = jnp.dot(x, wg_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (bm, bi)
    u = jnp.dot(x, wu_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (bm, bi)
    h = jax.nn.silu(g) * u
    o_ref[...] += jnp.dot(h, wd_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)  # (bm, H)


def expert_ffn(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    block_m: int = 64,
    block_i: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """SwiGLU FFN for a single expert.

    Args:
      x: (m, hidden) token batch routed to this expert.
      w_gate, w_up: (hidden, inter)
      w_down: (inter, hidden)

    Returns:
      (m, hidden) float32.
    """
    m, hidden = x.shape
    inter = w_gate.shape[1]
    assert w_gate.shape == (hidden, inter)
    assert w_up.shape == (hidden, inter)
    assert w_down.shape == (inter, hidden)

    block_m = largest_divisor_leq(m, block_m)
    block_i = largest_divisor_leq(inter, block_i)

    grid = (m // block_m, inter // block_i)

    return pl.pallas_call(
        _expert_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, hidden), lambda mt, it: (mt, 0)),
            pl.BlockSpec((hidden, block_i), lambda mt, it: (0, it)),
            pl.BlockSpec((hidden, block_i), lambda mt, it: (0, it)),
            pl.BlockSpec((block_i, hidden), lambda mt, it: (it, 0)),
        ],
        # Constant index along `it` → revisited block → f32 accumulator.
        out_specs=pl.BlockSpec((block_m, hidden), lambda mt, it: (mt, 0)),
        out_shape=jax.ShapeDtypeStruct((m, hidden), jnp.float32),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
