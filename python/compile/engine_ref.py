"""Reference engine: a python mirror of the rust coordinator's module loop.

Purpose: generate *golden traces* for rust integration tests.  It calls the
exact same jitted module functions that aot.py lowers to HLO, at the exact
same static batch buckets with the exact same padding rules, and performs
the host-side steps (KV-cache writes, expert gather/scatter, weighted
combine, residual adds) in the exact same order the rust engine does.
Because both sides run the same XLA programs on the same CPU backend and
the host-side f32 arithmetic is order-identical, the greedy token streams
must agree exactly (hidden states to ~1e-5).

Contract mirrored by rust (keep in sync with rust/src/engine/):
  * bucket(n) = smallest configured bucket >= n  (error if n > max).
  * flat-module padding: zero tokens, pos = 0, len = 0.
  * prefill pads every prompt to cfg.prefill_seq; positions of pads = 0.
  * expert grouping: experts visited in ascending id; within an expert,
    tokens in ascending flat-token order; combine acc[t] += w_rank * y.
  * shared expert added after routed experts; final x = resid + acc.
  * KV append happens BEFORE attn_decode (mask is kv_pos < len).
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .config import TinyMoEConfig
from . import model


def pick_bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch {n} exceeds max bucket {max(buckets)}")


class ReferenceEngine:
    """Greedy-decode MoE engine over the module-split model (python mirror)."""

    def __init__(self, cfg: TinyMoEConfig, weights: dict):
        self.cfg = cfg
        self.w = {k: np.asarray(v) for k, v in weights.items()}
        self._jits = {}

    # -- jitted module dispatch (cached per static shape) -------------------

    def _call(self, name, *args):
        fn = getattr(model, name)
        shapes = tuple((a.shape, str(a.dtype)) for a in args)
        key = (name, shapes)
        if key not in self._jits:
            self._jits[key] = jax.jit(functools.partial(fn, self.cfg))
        out = self._jits[key](*args)
        return tuple(np.asarray(o) for o in out)

    # -- host-side helpers ---------------------------------------------------

    def _pad_rows(self, x: np.ndarray, bucket: int) -> np.ndarray:
        if x.shape[0] == bucket:
            return x
        pad = np.zeros((bucket - x.shape[0],) + x.shape[1:], dtype=x.dtype)
        return np.concatenate([x, pad], axis=0)

    def _flat(self, name, weights, x_list, n_valid):
        """Run a flat-token module at its bucket; return unpadded outputs."""
        bucket = pick_bucket(n_valid, self.cfg.token_buckets)
        args = [np.asarray(w) for w in weights] + [
            self._pad_rows(np.asarray(x), bucket) for x in x_list
        ]
        outs = self._call(name, *args)
        return tuple(o[:n_valid] for o in outs)

    def _moe(self, layer: int, x: np.ndarray) -> np.ndarray:
        """Router + expert micro-batches + shared expert + residual."""
        cfg, w = self.cfg, self.w
        p = f"l{layer}."
        n = x.shape[0]
        xn, idx, wts = self._flat("router", [w[p + "ln2"], w[p + "wr"]], [x], n)

        acc = np.zeros_like(x, dtype=np.float32)
        for e in range(cfg.num_experts):
            rows, ranks = np.nonzero(idx == e)
            if rows.size == 0:
                continue
            bucket = pick_bucket(rows.size, cfg.expert_buckets)
            gathered = self._pad_rows(xn[rows], bucket)
            (y,) = self._call(
                "expert_ffn", w[p + f"e{e}.wg"], w[p + f"e{e}.wu"],
                w[p + f"e{e}.wd"], gathered,
            )
            acc[rows] += wts[rows, ranks][:, None] * y[: rows.size]

        if cfg.use_shared_expert:
            bucket = pick_bucket(n, cfg.expert_buckets)
            (ys,) = self._call(
                "expert_ffn", w[p + "se.wg"], w[p + "se.wu"], w[p + "se.wd"],
                self._pad_rows(xn, bucket),
            )
            acc += ys[:n]
        return x + acc

    # -- phases ---------------------------------------------------------------

    def prefill(self, prompts: List[List[int]]):
        """Process padded prompts; returns (kv_caches, lens, first_tokens).

        kv_caches: per-layer (k, v) arrays of shape (b, S, nkv, hd).
        """
        cfg, w = self.cfg, self.w
        b = len(prompts)
        s = cfg.prefill_seq
        lens = np.array([len(pr) for pr in prompts], dtype=np.int32)
        assert lens.max() <= s

        ids = np.zeros((b, s), dtype=np.int32)
        pos = np.zeros((b, s), dtype=np.int32)
        for i, pr in enumerate(prompts):
            ids[i, : len(pr)] = pr
            pos[i, : len(pr)] = np.arange(len(pr))

        n = b * s
        (x,) = self._flat("embed", [w["emb"]], [ids.reshape(n)], n)

        S = cfg.max_context
        caches = [
            (
                np.zeros((b, S, cfg.num_kv_heads, cfg.head_dim), np.float32),
                np.zeros((b, S, cfg.num_kv_heads, cfg.head_dim), np.float32),
            )
            for _ in range(cfg.num_layers)
        ]

        ab = pick_bucket(b, cfg.prefill_batch_buckets)
        for layer in range(cfg.num_layers):
            p = f"l{layer}."
            q, k, v = self._flat(
                "pre_attention",
                [w[p + "ln1"], w[p + "wq"], w[p + "wk"], w[p + "wv"]],
                [x, pos.reshape(n)],
                n,
            )
            qb = self._pad_rows(
                q.reshape(b, s, cfg.num_heads, cfg.head_dim), ab)
            kb = self._pad_rows(
                k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim), ab)
            vb = self._pad_rows(
                v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim), ab)
            lens_b = self._pad_rows(lens, ab)
            (ctx,) = self._call("attn_prefill", qb, kb, vb, lens_b)
            ctx = ctx[:b].reshape(n, cfg.q_dim)

            kc, vc = caches[layer]
            for i in range(b):
                kc[i, : lens[i]] = kb[i, : lens[i]]
                vc[i, : lens[i]] = vb[i, : lens[i]]

            (x,) = self._flat(
                "post_attention", [w[p + "wo"]], [ctx, x], n)
            x = self._moe(layer, x)

        # Last valid token of each sequence -> first generated token.
        last = np.stack([x[i * s + lens[i] - 1] for i in range(b)])
        (toks,) = self._flat("lm_head", [w["lnf"], w["lm_head"]], [last], b)
        return caches, lens.copy(), toks.astype(np.int32)

    def decode_step(self, caches, lens, tokens):
        """One greedy decode step for all sequences; mutates caches/lens."""
        cfg, w = self.cfg, self.w
        b = tokens.shape[0]
        pos = lens.astype(np.int32)  # next position per sequence

        (x,) = self._flat("embed", [w["emb"]], [tokens.astype(np.int32)], b)

        db = pick_bucket(b, cfg.decode_batch_buckets)
        new_lens = lens + 1
        for layer in range(cfg.num_layers):
            p = f"l{layer}."
            q, k, v = self._flat(
                "pre_attention",
                [w[p + "ln1"], w[p + "wq"], w[p + "wk"], w[p + "wv"]],
                [x, pos],
                b,
            )
            kc, vc = caches[layer]
            for i in range(b):
                kc[i, pos[i]] = k[i]
                vc[i, pos[i]] = v[i]

            qd = self._pad_rows(q, db)
            kd = self._pad_rows(kc, db)
            vd = self._pad_rows(vc, db)
            ld = self._pad_rows(new_lens.astype(np.int32), db)
            (ctx,) = self._call("attn_decode", qd, kd, vd, ld)
            ctx = ctx[:b]

            (x,) = self._flat("post_attention", [w[p + "wo"]], [ctx, x], b)
            x = self._moe(layer, x)

        (toks,) = self._flat("lm_head", [w["lnf"], w["lm_head"]], [x], b)
        lens += 1
        return toks.astype(np.int32)

    def generate(self, prompts: List[List[int]], steps: int) -> np.ndarray:
        """Greedy decode `steps` tokens; returns (b, steps) int32."""
        caches, lens, toks = self.prefill(prompts)
        out = [toks]
        for _ in range(steps - 1):
            toks = self.decode_step(caches, lens, toks)
            out.append(toks)
        return np.stack(out, axis=1)
